//! SubGen as a serving cache policy — Algorithm 1 fused with the
//! recent-tokens sliding window (the practical variant of §3.2).
//!
//! Tokens enter the recent window first; when they age out they flow
//! into the SubGen sketches (online key clustering + ℓ2 value sampling).
//! Attention combines the exact window part with the sketched estimate
//! of the older tokens through the shared packed-buffer estimator:
//!
//! * window tokens:   w = 1,           u = 1
//! * ℓ2 samples:      w = μ/(s·‖v‖²),  u = 0
//! * cluster samples: w = 0,           u = n_i/t

use super::{
    bytes_per_slot_encoded, CachePolicy, CacheTelemetry, KvDtype, PackedCache, SlidingCache,
};
use crate::io::Checkpoint;
use crate::subgen::{SubGenAttention, SubGenConfig};
use std::cell::RefCell;

/// Configuration for the hybrid SubGen cache.
#[derive(Debug, Clone, Copy)]
pub struct SubGenCacheConfig {
    /// Embedding dimension.
    pub dim: usize,
    /// Recent-window length r (0 disables the window).
    pub recent: usize,
    /// ℓ2 matrix-product samples s.
    pub s: usize,
    /// Uniform samples per cluster t.
    pub t: usize,
    /// Cluster threshold δ.
    pub delta: f32,
    /// Optional hard cap on clusters (diagnostics; None = unbounded).
    pub max_clusters: Option<usize>,
}

/// Persistent packed buffer for the batched host-attention path, so a
/// per-tick batched evaluation packs once and allocates nothing after
/// warm-up. Kernel scratch (`scores`/`zacc`) is caller-supplied — the
/// same convention as [`PackedCache::attention_batch_into`].
#[derive(Default)]
struct BatchScratch {
    buf: Option<PackedCache>,
}

/// Hybrid recent-window + SubGen-sketch cache policy.
pub struct SubGenCache {
    cfg: SubGenCacheConfig,
    recent: Option<SlidingCache>,
    sketch: SubGenAttention,
    n: u64,
    scratch: RefCell<BatchScratch>,
    enc: KvDtype,
}

impl SubGenCache {
    /// Build with explicit parameters; `seed` drives all sampling.
    pub fn new(cfg: SubGenCacheConfig, seed: u64) -> Self {
        let sketch_cfg = SubGenConfig {
            dim: cfg.dim,
            delta: cfg.delta.max(1e-9),
            t: cfg.t.max(1),
            s: cfg.s.max(1),
        };
        Self {
            cfg,
            recent: (cfg.recent > 0).then(|| SlidingCache::new(cfg.dim, cfg.recent)),
            sketch: SubGenAttention::new(sketch_cfg, seed),
            n: 0,
            scratch: RefCell::new(BatchScratch::default()),
            enc: KvDtype::F32,
        }
    }

    /// Clusters discovered by the sketch so far.
    pub fn num_clusters(&self) -> usize {
        self.sketch.num_clusters()
    }

    /// The underlying sketch (diagnostics).
    pub fn sketch(&self) -> &SubGenAttention {
        &self.sketch
    }

    /// Batched host attention into a caller buffer (`nq × dim`): one
    /// pack into the persistent scratch buffer, then one batched sweep.
    /// `scores`/`zacc` are caller-owned kernel scratch (resized as
    /// needed) — the same signature shape as
    /// [`PackedCache::attention_batch_into`], so callers hold one set of
    /// scratch vectors across every `_into` attention entry point.
    /// Allocation-free after warm-up at a stable packed-slot count.
    pub fn attention_batch_into(
        &self,
        qs: &[f32],
        nq: usize,
        scores: &mut Vec<f32>,
        zacc: &mut Vec<f64>,
        out: &mut [f32],
    ) {
        let mut scratch = self.scratch.borrow_mut();
        let buf = PackedCache::ensure_scratch(
            &mut scratch.buf,
            self.cfg.dim,
            self.packed_slots(),
            self.enc,
        );
        self.pack(buf);
        buf.attention_batch_into(qs, nq, scores, zacc, out);
    }
}

impl CachePolicy for SubGenCache {
    fn name(&self) -> &'static str {
        "subgen"
    }

    fn update(&mut self, q: &[f32], k: &[f32], v: &[f32]) {
        match &mut self.recent {
            Some(window) => {
                // Oldest window token graduates into the sketch.
                if window.retained() == window.window() {
                    let gk = window.key_at(0).to_vec();
                    let gv = window.value_at(0).to_vec();
                    self.sketch.update(&gk, &gv);
                }
                window.update(q, k, v);
            }
            None => self.sketch.update(k, v),
        }
        // Keep the clustered share inside its budget by δ-doubling when
        // the stream turns out less clusterable than configured.
        if let Some(cap) = self.cfg.max_clusters {
            self.sketch.enforce_cluster_cap(cap);
        }
        self.n += 1;
    }

    fn pack(&self, buf: &mut PackedCache) {
        buf.clear();
        // 1. Recent window: exact contribution to both paths.
        if let Some(window) = &self.recent {
            for i in 0..window.retained() {
                buf.push(window.key_at(i), window.value_at(i), 1.0, 1.0);
            }
        }
        // 2. ℓ2 matrix-product samples: numerator only (rows stream
        // straight out of the sketch's contiguous arenas).
        let mp = self.sketch.matrix_product();
        let mu = mp.mass();
        let s = mp.num_slots() as f64;
        for sample in mp.samples() {
            if sample.v_norm_sq > 0.0 {
                let w = (mu / (s * sample.v_norm_sq)) as f32;
                buf.push(sample.k, sample.v, w, 0.0);
            }
        }
        // 3. Cluster samples: normalizer only (zero value rows written
        // in place — no temporary zero vector per slot).
        let nz = self.sketch.normalizer();
        let t = nz.t() as f32;
        for c in 0..nz.num_clusters() {
            let u = nz.cluster_count(c) as f32 / t;
            for key in nz.cluster_samples(c) {
                buf.push_normalizer(key, u);
            }
        }
    }

    fn len(&self) -> u64 {
        self.n
    }

    fn packed_slots(&self) -> usize {
        let window = self.recent.as_ref().map(|w| w.retained()).unwrap_or(0);
        let mp = self.sketch.matrix_product().num_slots();
        let nz = self.sketch.normalizer();
        window + mp + nz.num_clusters() * nz.t()
    }

    fn kv_encoding(&self) -> KvDtype {
        self.enc
    }

    fn set_kv_encoding(&mut self, enc: KvDtype) {
        self.enc = enc;
    }

    fn telemetry(&self, dim: usize) -> CacheTelemetry {
        let slots = self.packed_slots() as u64;
        let bytes = slots * bytes_per_slot_encoded(dim, self.enc) as u64;
        CacheTelemetry {
            slots,
            bytes,
            admitted: self.n,
            // Graduated tokens live on only as cluster/reservoir
            // summaries — everything beyond the retained slots.
            evicted: self.n.saturating_sub(slots),
            clusters: self.sketch.num_clusters() as u64,
            reservoir: self.sketch.matrix_product().num_slots() as u64,
            resident_bytes: bytes,
            spilled_bytes: 0,
        }
    }

    fn attention_batch(&self, qs: &[f32], nq: usize) -> Vec<f32> {
        if nq == 0 {
            return Vec::new();
        }
        assert_eq!(qs.len() % nq, 0, "qs must be nq × dim row-major");
        let mut out = vec![0.0f32; qs.len()];
        let (mut scores, mut zacc) = (Vec::new(), Vec::new());
        self.attention_batch_into(qs, nq, &mut scores, &mut zacc, &mut out);
        out
    }

    fn save_state(&self, ck: &mut Checkpoint, prefix: &str) {
        ck.insert_u64s(&format!("{prefix}/n"), &[self.n]);
        if let Some(window) = &self.recent {
            window.save_state(ck, &format!("{prefix}/recent"));
        }
        self.sketch.save_state(ck, &format!("{prefix}/sketch"));
    }

    fn restore_state(&mut self, ck: &Checkpoint, prefix: &str) -> anyhow::Result<()> {
        let n = ck.require_u64s(&format!("{prefix}/n"))?;
        anyhow::ensure!(n.len() == 1, "{prefix}/n: expected 1 entry");
        self.n = n[0];
        if let Some(window) = &mut self.recent {
            window.restore_state(ck, &format!("{prefix}/recent"))?;
        }
        // The sketch config re-derives from this cache's own config (the
        // same clamping `new` applied), so only dynamic state is stored.
        self.sketch =
            SubGenAttention::restore_state(*self.sketch.config(), ck, &format!("{prefix}/sketch"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::linalg::rel_err_vec;
    use crate::rng::{Pcg64, Rng};
    use crate::tensor::Tensor;

    /// Clusterable key stream with smooth values.
    fn stream(n: usize, m: usize, dim: usize, sigma: f32, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let centers: Vec<Vec<f32>> =
            (0..m).map(|_| (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect()).collect();
        let mut keys = Tensor::zeros(0, dim);
        let mut values = Tensor::zeros(0, dim);
        let mut queries = Tensor::zeros(0, dim);
        for i in 0..n {
            let c = &centers[i % m];
            keys.push_row(&c.iter().map(|&x| x + rng.gaussian32(0.0, sigma)).collect::<Vec<_>>());
            values.push_row(&(0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect::<Vec<_>>());
            queries.push_row(&(0..dim).map(|_| rng.gaussian32(0.0, 0.3)).collect::<Vec<_>>());
        }
        (keys, values, queries)
    }

    /// Eq. 3 of the paper: ‖z − Attn‖₂ ≤ ε·‖softmax(K·q)‖₂·‖V‖_op.
    /// With s = Θ(d/ε²) and t = Θ(ε⁻²·e^{2δr}·log n), ε here ≈ 0.5.
    #[test]
    fn satisfies_spectral_error_bound_on_clusterable_stream() {
        let dim = 16;
        let n = 1200;
        let (keys, values, queries) = stream(n, 6, dim, 0.03, 31);
        let cfg =
            SubGenCacheConfig { dim, recent: 64, s: 256, t: 64, delta: 0.4, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 5);
        for i in 0..n {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        let q = queries.row(n - 1);
        let got = c.attention(q);
        let want = exact_attention(q, &keys, &values);
        let err: f32 = got.iter().zip(&want).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let rhs = crate::attention::error_bound_rhs(0.5, q, &keys, &values);
        assert!(err <= rhs, "err={err} rhs={rhs}");
        assert!(c.num_clusters() <= 12, "m={}", c.num_clusters());
    }

    /// In the low-variance regime for ℓ2 sampling (values sharing a
    /// dominant direction with equal norms), the *relative output error*
    /// is small too.
    #[test]
    fn low_relative_error_with_aligned_values() {
        let dim = 16;
        let n = 1200;
        let (keys, _, queries) = stream(n, 6, dim, 0.03, 41);
        let mut rng = Pcg64::seed_from_u64(42);
        let base: Vec<f32> = (0..dim).map(|i| ((i as f32) * 0.4).cos()).collect();
        let mut values = Tensor::zeros(0, dim);
        for _ in 0..n {
            values
                .push_row(&base.iter().map(|&b| b + rng.gaussian32(0.0, 0.1)).collect::<Vec<_>>());
        }
        let cfg =
            SubGenCacheConfig { dim, recent: 64, s: 256, t: 64, delta: 0.4, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 6);
        for i in 0..n {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        let q = queries.row(n - 1);
        let got = c.attention(q);
        let want = exact_attention(q, &keys, &values);
        let err = rel_err_vec(&got, &want);
        assert!(err < 0.1, "err={err}");
    }

    /// δ-doubling keeps the cluster count (and so memory) capped on an
    /// adversarially unclusterable stream.
    #[test]
    fn cluster_cap_bounds_memory_on_random_stream() {
        let dim = 8;
        let mut rng = Pcg64::seed_from_u64(51);
        let cfg =
            SubGenCacheConfig { dim, recent: 8, s: 8, t: 4, delta: 0.1, max_clusters: Some(6) };
        let mut c = SubGenCache::new(cfg, 7);
        for _ in 0..800 {
            let k: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 2.0)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect();
            c.update(&[0.0; 8], &k, &v);
        }
        assert!(c.num_clusters() <= 6, "m={}", c.num_clusters());
        assert!(c.packed_slots() <= 8 + 8 + 6 * 4);
        // Population accounting survives merges.
        let nz = c.sketch().normalizer();
        let pop: u64 = (0..nz.num_clusters()).map(|i| nz.cluster_count(i)).sum();
        assert_eq!(pop, 800 - 8); // all graduated tokens
    }

    #[test]
    fn window_only_prefix_is_exact() {
        let dim = 8;
        let (keys, values, queries) = stream(40, 4, dim, 0.1, 32);
        let cfg = SubGenCacheConfig { dim, recent: 64, s: 8, t: 4, delta: 0.5, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 1);
        for i in 0..40 {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        // All 40 tokens still in the window: must equal exact attention.
        let q = queries.row(39);
        let got = c.attention(q);
        let want = exact_attention(q, &keys, &values);
        assert!(rel_err_vec(&got, &want) < 1e-5);
    }

    /// The batched path (pack once + one sweep) must agree exactly with
    /// the per-query `attention` (pack per query).
    #[test]
    fn attention_batch_matches_attention_loop() {
        let dim = 8;
        let n = 600;
        let (keys, values, queries) = stream(n, 4, dim, 0.05, 61);
        let cfg =
            SubGenCacheConfig { dim, recent: 32, s: 64, t: 8, delta: 0.4, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 9);
        for i in 0..n {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        let nq = 6;
        let mut qs = Vec::with_capacity(nq * dim);
        for b in 0..nq {
            qs.extend_from_slice(queries.row(n - 1 - b));
        }
        let batched = c.attention_batch(&qs, nq);
        assert_eq!(batched.len(), nq * dim);
        for b in 0..nq {
            let want = c.attention(&qs[b * dim..(b + 1) * dim]);
            assert_eq!(&batched[b * dim..(b + 1) * dim], &want[..], "b={b}");
        }
        // Warmed scratch: a second batch call reuses the same buffer.
        let again = c.attention_batch(&qs, nq);
        assert_eq!(again, batched);
    }

    #[test]
    fn memory_sublinear_vs_exact() {
        let dim = 8;
        let n = 4000;
        let (keys, values, queries) = stream(n, 4, dim, 0.02, 33);
        let cfg =
            SubGenCacheConfig { dim, recent: 32, s: 32, t: 8, delta: 0.4, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 2);
        for i in 0..n {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        let exact_bytes = n * super::super::bytes_per_slot(dim);
        let got = c.memory_bytes(dim);
        assert!(got * 10 < exact_bytes, "got={got} exact={exact_bytes}");
    }

    #[test]
    fn no_window_variant_satisfies_bound() {
        let dim = 8;
        let (keys, values, queries) = stream(500, 4, dim, 0.02, 34);
        let cfg =
            SubGenCacheConfig { dim, recent: 0, s: 128, t: 32, delta: 0.4, max_clusters: None };
        let mut c = SubGenCache::new(cfg, 3);
        for i in 0..500 {
            c.update(queries.row(i), keys.row(i), values.row(i));
        }
        let q = queries.row(499);
        let got = c.attention(q);
        let want = exact_attention(q, &keys, &values);
        let err: f32 = got.iter().zip(&want).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
        let rhs = crate::attention::error_bound_rhs(0.75, q, &keys, &values);
        assert!(err <= rhs, "err={err} rhs={rhs}");
        assert_eq!(c.len(), 500);
    }
}
