//! Attention-sink cache (Xiao et al., StreamingLLM — the paper's "Sink"
//! baseline): deterministically keep the first `n_sink` tokens plus a
//! sliding window of the most recent tokens.

use super::{CachePolicy, KvDtype, PackedCache, SlidingCache};
use crate::io::Checkpoint;

/// First-`n_sink` + recent-`window` eviction policy.
#[derive(Debug, Clone)]
pub struct SinkCache {
    dim: usize,
    n_sink: usize,
    /// The first n_sink (k, v) pairs, in arrival order.
    sink_keys: Vec<f32>,
    sink_values: Vec<f32>,
    stored_sinks: usize,
    recent: SlidingCache,
    n: u64,
    enc: KvDtype,
}

impl SinkCache {
    /// `n_sink` initial tokens + `window` most recent.
    pub fn new(dim: usize, n_sink: usize, window: usize) -> Self {
        Self {
            dim,
            n_sink,
            sink_keys: vec![0.0; n_sink * dim],
            sink_values: vec![0.0; n_sink * dim],
            stored_sinks: 0,
            recent: SlidingCache::new(dim, window.max(1)),
            n: 0,
            enc: KvDtype::F32,
        }
    }
}

impl CachePolicy for SinkCache {
    fn name(&self) -> &'static str {
        "sink"
    }

    fn update(&mut self, q: &[f32], k: &[f32], v: &[f32]) {
        if self.stored_sinks < self.n_sink {
            let at = self.stored_sinks * self.dim;
            self.sink_keys[at..at + self.dim].copy_from_slice(k);
            self.sink_values[at..at + self.dim].copy_from_slice(v);
            self.stored_sinks += 1;
        } else {
            self.recent.update(q, k, v);
        }
        self.n += 1;
    }

    fn pack(&self, buf: &mut PackedCache) {
        buf.clear();
        for i in 0..self.stored_sinks {
            buf.push(
                &self.sink_keys[i * self.dim..(i + 1) * self.dim],
                &self.sink_values[i * self.dim..(i + 1) * self.dim],
                1.0,
                1.0,
            );
        }
        for i in 0..self.recent.retained() {
            buf.push(self.recent.key_at(i), self.recent.value_at(i), 1.0, 1.0);
        }
    }

    fn len(&self) -> u64 {
        self.n
    }

    fn packed_slots(&self) -> usize {
        self.stored_sinks + self.recent.retained()
    }

    fn kv_encoding(&self) -> KvDtype {
        self.enc
    }

    fn set_kv_encoding(&mut self, enc: KvDtype) {
        // `recent` is an internal ring kept in f32 (sink packs its rows
        // itself), so only the sink-level encoding matters for packing.
        self.enc = enc;
    }

    fn save_state(&self, ck: &mut Checkpoint, prefix: &str) {
        ck.insert(
            &format!("{prefix}/sink_keys"),
            vec![self.n_sink, self.dim],
            self.sink_keys.clone(),
        );
        ck.insert(
            &format!("{prefix}/sink_values"),
            vec![self.n_sink, self.dim],
            self.sink_values.clone(),
        );
        ck.insert_u64s(&format!("{prefix}/meta"), &[self.stored_sinks as u64, self.n]);
        self.recent.save_state(ck, &format!("{prefix}/recent"));
    }

    fn restore_state(&mut self, ck: &Checkpoint, prefix: &str) -> anyhow::Result<()> {
        let keys = ck.require(&format!("{prefix}/sink_keys"))?;
        let values = ck.require(&format!("{prefix}/sink_values"))?;
        anyhow::ensure!(
            keys.dims == [self.n_sink, self.dim] && values.dims == [self.n_sink, self.dim],
            "{prefix}: sink shape mismatch (n_sink {}, dim {})",
            self.n_sink,
            self.dim
        );
        self.sink_keys.copy_from_slice(&keys.data);
        self.sink_values.copy_from_slice(&values.data);
        let meta = ck.require_u64s(&format!("{prefix}/meta"))?;
        anyhow::ensure!(meta.len() == 2, "{prefix}/meta: expected 2 entries");
        anyhow::ensure!(meta[0] as usize <= self.n_sink, "{prefix}: stored_sinks over capacity");
        self.stored_sinks = meta[0] as usize;
        self.n = meta[1];
        self.recent.restore_state(ck, &format!("{prefix}/recent"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retains_first_and_last() {
        let dim = 2;
        let mut c = SinkCache::new(dim, 2, 3);
        for i in 0..10 {
            c.update(&[0.0; 2], &[i as f32; 2], &[i as f32; 2]);
        }
        let mut buf = PackedCache::new(dim, c.packed_slots());
        c.pack(&mut buf);
        assert_eq!(buf.used(), 5);
        // Sinks = tokens 0,1; recent = 7,8,9.
        assert_eq!(buf.value(0), &[0.0, 0.0]);
        assert_eq!(buf.value(1), &[1.0, 1.0]);
        assert_eq!(buf.value(2), &[7.0, 7.0]);
        assert_eq!(buf.value(4), &[9.0, 9.0]);
    }

    #[test]
    fn zero_sinks_degenerates_to_sliding() {
        let dim = 2;
        let mut c = SinkCache::new(dim, 0, 2);
        for i in 0..5 {
            c.update(&[0.0; 2], &[i as f32; 2], &[i as f32; 2]);
        }
        let mut buf = PackedCache::new(dim, 2);
        c.pack(&mut buf);
        assert_eq!(buf.used(), 2);
        assert_eq!(buf.value(0), &[3.0, 3.0]);
        assert_eq!(buf.value(1), &[4.0, 4.0]);
    }

    #[test]
    fn memory_bounded() {
        let dim = 4;
        let mut c = SinkCache::new(dim, 4, 8);
        for i in 0..1000 {
            c.update(&[0.0; 4], &[i as f32; 4], &[1.0; 4]);
        }
        assert!(c.memory_bytes(dim) <= 12 * super::super::bytes_per_slot(dim));
    }

    #[test]
    fn telemetry_matches_packed_slots() {
        let dim = 4;
        let mut c = SinkCache::new(dim, 4, 8);
        for i in 0..1000 {
            c.update(&[0.0; 4], &[i as f32; 4], &[1.0; 4]);
        }
        let t = c.telemetry(dim);
        assert_eq!(t.admitted, 1000);
        assert_eq!(t.slots as usize, c.packed_slots());
        assert_eq!(t.evicted, 1000 - t.slots);
    }
}
