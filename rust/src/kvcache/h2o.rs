//! H2O — Heavy-Hitter Oracle (Zhang et al., NeurIPS'23), the paper's
//! strongest baseline in Table 1.
//!
//! Greedy eviction by *accumulated attention score*: every step, the
//! current query's softmax weights over the retained keys (plus the new
//! token) are added to per-token accumulators; when the heavy-hitter
//! region exceeds its budget the token with the smallest accumulated
//! score is evicted. A separate recent window is always retained, as in
//! the original system.

use super::{
    bytes_per_slot_encoded, CachePolicy, CacheTelemetry, KvDtype, PackedCache, SlidingCache,
};
use crate::io::Checkpoint;
use crate::tensor::dot;

/// One retained heavy-hitter candidate.
#[derive(Debug, Clone)]
struct Entry {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Accumulated attention mass this token has received.
    score: f64,
}

/// Heavy-hitter cache: `budget` scored tokens + `window` recent tokens.
#[derive(Debug, Clone)]
pub struct H2OCache {
    budget: usize,
    entries: Vec<Entry>,
    recent: SlidingCache,
    n: u64,
    enc: KvDtype,
}

impl H2OCache {
    /// `budget` heavy-hitter slots + `window` recent slots.
    pub fn new(dim: usize, budget: usize, window: usize) -> Self {
        let _ = dim; // recorded implicitly by the ring/entry vectors
        Self {
            budget: budget.max(1),
            entries: Vec::new(),
            recent: SlidingCache::new(dim, window.max(1)),
            n: 0,
            enc: KvDtype::F32,
        }
    }

    /// Accumulate this step's attention distribution into the per-token
    /// scores (softmax over retained heavy hitters ∪ recent ∪ new token;
    /// only heavy-hitter accumulators are updated — recency protects the
    /// window anyway).
    fn accumulate(&mut self, q: &[f32], new_k: &[f32]) {
        let mut scores: Vec<f32> = self.entries.iter().map(|e| dot(&e.k, q)).collect();
        let recent_scores: Vec<f32> =
            (0..self.recent.retained()).map(|i| dot(self.recent.key_at(i), q)).collect();
        scores.extend_from_slice(&recent_scores);
        scores.push(dot(new_k, q));
        let lse = crate::linalg::logsumexp(&scores);
        if !lse.is_finite() {
            return;
        }
        for (e, &sc) in self.entries.iter_mut().zip(scores.iter()) {
            e.score += ((sc - lse) as f64).exp();
        }
    }

    /// Number of retained heavy hitters.
    pub fn num_heavy(&self) -> usize {
        self.entries.len()
    }
}

impl CachePolicy for H2OCache {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn update(&mut self, q: &[f32], k: &[f32], v: &[f32]) {
        self.accumulate(q, k);
        // Token leaving the recent window graduates to heavy-hitter
        // consideration: when the window is full, its oldest token moves
        // into the scored region before the new token enters the ring.
        let was_full = self.recent.retained() == self.recent.window();
        let graduate: Option<(Vec<f32>, Vec<f32>)> = if was_full {
            Some((self.recent.key_at(0).to_vec(), self.recent.value_at(0).to_vec()))
        } else {
            None
        };
        self.recent.update(q, k, v);
        if let Some((gk, gv)) = graduate {
            // Seed the graduate with the mean heavy-hitter score so it is
            // not instantly evicted before receiving any attention.
            let seed = if self.entries.is_empty() {
                0.0
            } else {
                self.entries.iter().map(|e| e.score).sum::<f64>() / self.entries.len() as f64
            };
            self.entries.push(Entry { k: gk, v: gv, score: seed });
            if self.entries.len() > self.budget {
                // Evict the minimum accumulated score (greedy H2O rule).
                let (idx, _) = self
                    .entries
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.score.partial_cmp(&b.1.score).unwrap())
                    .unwrap();
                self.entries.swap_remove(idx);
            }
        }
        self.n += 1;
    }

    fn pack(&self, buf: &mut PackedCache) {
        buf.clear();
        for e in &self.entries {
            buf.push(&e.k, &e.v, 1.0, 1.0);
        }
        for i in 0..self.recent.retained() {
            buf.push(self.recent.key_at(i), self.recent.value_at(i), 1.0, 1.0);
        }
    }

    fn len(&self) -> u64 {
        self.n
    }

    fn packed_slots(&self) -> usize {
        self.entries.len() + self.recent.retained()
    }

    fn kv_encoding(&self) -> KvDtype {
        self.enc
    }

    fn set_kv_encoding(&mut self, enc: KvDtype) {
        self.enc = enc;
    }

    fn telemetry(&self, dim: usize) -> CacheTelemetry {
        let slots = self.packed_slots() as u64;
        let bytes = slots * bytes_per_slot_encoded(dim, self.enc) as u64;
        CacheTelemetry {
            slots,
            bytes,
            admitted: self.n,
            evicted: self.n.saturating_sub(slots),
            clusters: 0,
            // The scored heavy-hitter set plays the reservoir role.
            reservoir: self.entries.len() as u64,
            resident_bytes: bytes,
            spilled_bytes: 0,
        }
    }

    fn save_state(&self, ck: &mut Checkpoint, prefix: &str) {
        // Entry order matters (swap_remove shapes it), so entries are
        // stored positionally; scores ride the exact f64 codec since
        // future evictions compare them.
        let dim = self.recent.dim();
        let m = self.entries.len();
        let mut keys = Vec::with_capacity(m * dim);
        let mut values = Vec::with_capacity(m * dim);
        let mut scores = Vec::with_capacity(m);
        for e in &self.entries {
            keys.extend_from_slice(&e.k);
            values.extend_from_slice(&e.v);
            scores.push(e.score);
        }
        ck.insert(&format!("{prefix}/hh_keys"), vec![m, dim], keys);
        ck.insert(&format!("{prefix}/hh_values"), vec![m, dim], values);
        ck.insert_f64s(&format!("{prefix}/hh_scores"), &scores);
        ck.insert_u64s(&format!("{prefix}/n"), &[self.n]);
        self.recent.save_state(ck, &format!("{prefix}/recent"));
    }

    fn restore_state(&mut self, ck: &Checkpoint, prefix: &str) -> anyhow::Result<()> {
        let dim = self.recent.dim();
        let keys = ck.require(&format!("{prefix}/hh_keys"))?;
        let values = ck.require(&format!("{prefix}/hh_values"))?;
        let scores = ck.require_f64s(&format!("{prefix}/hh_scores"))?;
        let m = scores.len();
        anyhow::ensure!(
            keys.dims == [m, dim] && values.dims == [m, dim],
            "{prefix}: heavy-hitter shape mismatch (m {m}, dim {dim})"
        );
        let budget = self.budget;
        anyhow::ensure!(m <= budget, "{prefix}: {m} heavy hitters over budget {budget}");
        self.entries = (0..m)
            .map(|i| Entry {
                k: keys.data[i * dim..(i + 1) * dim].to_vec(),
                v: values.data[i * dim..(i + 1) * dim].to_vec(),
                score: scores[i],
            })
            .collect();
        let n = ck.require_u64s(&format!("{prefix}/n"))?;
        anyhow::ensure!(n.len() == 1, "{prefix}/n: expected 1 entry");
        self.n = n[0];
        self.recent.restore_state(ck, &format!("{prefix}/recent"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn heavy_hitter_survives_eviction() {
        let dim = 4;
        // One "pivotal" key aligned with every query; distractor keys
        // orthogonal. The pivotal token must survive.
        let mut c = H2OCache::new(dim, 4, 2);
        let pivot_k = [4.0f32, 0.0, 0.0, 0.0];
        let pivot_v = [9.0f32; 4];
        let q = [1.0f32, 0.0, 0.0, 0.0];
        c.update(&q, &pivot_k, &pivot_v);
        let mut rng = Pcg64::seed_from_u64(8);
        for _ in 0..60 {
            let k = [0.0, rng.gaussian32(0.0, 0.2), rng.gaussian32(0.0, 0.2), 0.0];
            let v = [1.0f32; 4];
            c.update(&q, &k, &v);
        }
        // Pivot value 9.0 should still be retrievable: attention output
        // dominated by pivot for this query.
        let out = c.attention(&q);
        assert!(out[0] > 5.0, "pivot evicted? out={out:?}");
    }

    #[test]
    fn budget_respected() {
        let dim = 4;
        let mut c = H2OCache::new(dim, 5, 3);
        let mut rng = Pcg64::seed_from_u64(9);
        for _ in 0..100 {
            let k: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect();
            c.update(&[1.0; 4], &k, &[1.0; 4]);
        }
        assert!(c.num_heavy() <= 5);
        assert!(c.packed_slots() <= 8);
    }

    #[test]
    fn scores_accumulate_monotonically() {
        let dim = 2;
        let mut c = H2OCache::new(dim, 4, 1);
        for i in 0..10 {
            c.update(&[1.0, 0.0], &[i as f32 * 0.01, 1.0], &[1.0, 1.0]);
        }
        for e in &c.entries {
            assert!(e.score >= 0.0);
        }
    }
}
