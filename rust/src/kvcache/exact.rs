//! Exact (uncompressed) KV cache — the paper's "Exact" row in Table 1
//! and the correctness oracle for every other policy.

use super::{CachePolicy, KvDtype, PackedCache};
use crate::io::Checkpoint;
use crate::tensor::Tensor;

/// Stores every (k, v) pair; O(n·d) memory, the baseline SubGen beats.
#[derive(Debug, Clone)]
pub struct ExactCache {
    keys: Tensor,
    values: Tensor,
    enc: KvDtype,
}

impl ExactCache {
    /// Empty cache over `dim`-dimensional tokens.
    pub fn new(dim: usize) -> Self {
        Self { keys: Tensor::zeros(0, dim), values: Tensor::zeros(0, dim), enc: KvDtype::F32 }
    }

    /// Full key history (rows = tokens).
    pub fn keys(&self) -> &Tensor {
        &self.keys
    }

    /// Full value history.
    pub fn values(&self) -> &Tensor {
        &self.values
    }
}

impl CachePolicy for ExactCache {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn update(&mut self, _q: &[f32], k: &[f32], v: &[f32]) {
        self.keys.push_row(k);
        self.values.push_row(v);
    }

    fn pack(&self, buf: &mut PackedCache) {
        buf.clear();
        for i in 0..self.keys.rows() {
            buf.push(self.keys.row(i), self.values.row(i), 1.0, 1.0);
        }
    }

    fn packed_append_only(&self) -> bool {
        true
    }

    fn kv_encoding(&self) -> KvDtype {
        self.enc
    }

    fn set_kv_encoding(&mut self, enc: KvDtype) {
        self.enc = enc;
    }

    fn pack_from(&self, buf: &mut PackedCache, from: usize) {
        buf.clear();
        for i in from..self.keys.rows() {
            buf.push(self.keys.row(i), self.values.row(i), 1.0, 1.0);
        }
    }

    fn len(&self) -> u64 {
        self.keys.rows() as u64
    }

    fn packed_slots(&self) -> usize {
        self.keys.rows()
    }

    fn save_state(&self, ck: &mut Checkpoint, prefix: &str) {
        let dim = self.keys.cols();
        let rows = self.keys.rows();
        ck.insert(&format!("{prefix}/keys"), vec![rows, dim], self.keys.as_slice().into());
        ck.insert(&format!("{prefix}/values"), vec![rows, dim], self.values.as_slice().into());
    }

    fn restore_state(&mut self, ck: &Checkpoint, prefix: &str) -> anyhow::Result<()> {
        let dim = self.keys.cols();
        let keys = ck.require(&format!("{prefix}/keys"))?;
        let values = ck.require(&format!("{prefix}/values"))?;
        anyhow::ensure!(
            keys.dims.len() == 2 && keys.dims[1] == dim && values.dims == keys.dims,
            "{prefix}: history shape mismatch (dim {dim})"
        );
        self.keys = Tensor::from_vec(keys.data.clone(), keys.dims[0], dim);
        self.values = Tensor::from_vec(values.data.clone(), values.dims[0], dim);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::rng::Pcg64;

    #[test]
    fn matches_reference_attention_exactly() {
        let dim = 8;
        let mut rng = Pcg64::seed_from_u64(4);
        let keys = Tensor::randn(&mut rng, 30, dim, 0.4);
        let values = Tensor::randn(&mut rng, 30, dim, 1.0);
        let mut c = ExactCache::new(dim);
        for i in 0..30 {
            c.update(&[0.0; 8], keys.row(i), values.row(i));
        }
        let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.3).sin()).collect();
        let got = c.attention(&q);
        let want = exact_attention(&q, &keys, &values);
        assert!(crate::linalg::rel_err_vec(&got, &want) < 1e-5);
    }

    #[test]
    fn memory_linear_in_n() {
        let mut c = ExactCache::new(4);
        for _ in 0..10 {
            c.update(&[0.0; 4], &[1.0; 4], &[1.0; 4]);
        }
        let m10 = c.memory_bytes(4);
        for _ in 0..10 {
            c.update(&[0.0; 4], &[1.0; 4], &[1.0; 4]);
        }
        assert_eq!(c.memory_bytes(4), 2 * m10);
    }

    #[test]
    fn telemetry_reports_full_retention() {
        let mut c = ExactCache::new(4);
        for _ in 0..10 {
            c.update(&[0.0; 4], &[1.0; 4], &[1.0; 4]);
        }
        let t = c.telemetry(4);
        assert_eq!(t.admitted, 10);
        assert_eq!(t.slots, 10);
        assert_eq!(t.evicted, 0);
        assert_eq!(t.clusters, 0);
        assert_eq!(t.bytes as usize, c.memory_bytes(4));
    }
}
