//! The fixed-capacity packed cache buffer shared by every policy and by
//! the XLA kernel.

use crate::tensor::{dot, scores_batch_encoded_into, scores_batch_into, KvArena, KvDtype, KvSlice};

/// Scratch-growth policy: capacity for `slots` rows plus ~50% headroom.
fn grown_capacity(slots: usize) -> usize {
    let n = slots.max(1);
    n + n / 2 + 8
}

/// The weighted-exponential estimator evaluated over *borrowed* flat
/// buffers — the single implementation behind every host attention
/// path. `keys`/`values` are `[n, dim]` row-major with per-slot weights
/// `w` (value path) and `u` (normalizer path), `n = w.len()`; `qs`
/// holds `nq` queries row-major; `extra` optionally appends one more
/// (key, value) slot *per query* with `w = u = 1` — each slice is
/// `[nq, dim]` row-major, query `b` seeing slot `b` — the decode step's
/// own token, which lives in the executable's reserved slot rather than
/// in the packed history. `scores` and `zacc` are caller scratch reused
/// across calls; `out` must be `nq × dim`.
///
/// The evaluation is batched row-major: every K row is scored once
/// against the whole query batch ([`scores_batch_into`]) and every V
/// row is loaded once and accumulated into all `nq` per-query f64
/// accumulators while hot — so a group of queries sharing one packed
/// buffer (parallel branches decoding over a shared context) pays for
/// each cached row once per call instead of once per query. Each
/// query's accumulation still walks slots in index order, so per-query
/// results are bit-identical to `nq` independent single-query calls.
///
/// [`PackedCache::attention_batch_into`] delegates here with
/// `extra = None`, so the owned-buffer and borrowed-buffer paths (the
/// cache policies and the host executor's decode over [`FlatCaches`])
/// compute bit-identical math.
///
/// [`FlatCaches`]: crate::model::FlatCaches
pub fn attention_flat_into(
    keys: &[f32],
    values: &[f32],
    w: &[f32],
    u: &[f32],
    dim: usize,
    qs: &[f32],
    nq: usize,
    extra: Option<(&[f32], &[f32])>,
    scores: &mut Vec<f32>,
    zacc: &mut Vec<f64>,
    out: &mut [f32],
) {
    let n = w.len();
    debug_assert_eq!(keys.len(), n * dim, "keys must be n × dim");
    debug_assert_eq!(values.len(), n * dim, "values must be n × dim");
    debug_assert_eq!(u.len(), n, "w/u length mismatch");
    assert_eq!(qs.len(), nq * dim, "qs must be nq × dim");
    assert_eq!(out.len(), nq * dim, "out must be nq × dim");
    if let Some((k_new, v_new)) = extra {
        assert_eq!(k_new.len(), nq * dim, "extra keys must be nq × dim");
        assert_eq!(v_new.len(), nq * dim, "extra values must be nq × dim");
    }
    for o in out.iter_mut() {
        *o = 0.0;
    }
    if (n == 0 && extra.is_none()) || nq == 0 {
        return;
    }
    // Scratch layout: `scores` holds the n × nq history scores plus, at
    // the tail, nq extra-slot scores and nq per-query max shifts;
    // `zacc` holds nq per-query dim-wide accumulators plus nq
    // normalizers at the tail.
    scores.resize(n * nq + 2 * nq, 0.0);
    let (hist, tail) = scores.split_at_mut(n * nq);
    let (extra_scores, shifts) = tail.split_at_mut(nq);
    scores_batch_into(keys, dim, qs, nq, hist);
    for b in 0..nq {
        let q = &qs[b * dim..(b + 1) * dim];
        extra_scores[b] = match extra {
            Some((k_new, _)) => dot(&k_new[b * dim..(b + 1) * dim], q),
            None => f32::NEG_INFINITY,
        };
        // Masked max over slots that matter (w or u positive), with the
        // extra slot (unit weights) always participating.
        let mut shift = extra_scores[b];
        for i in 0..n {
            let sc = hist[i * nq + b];
            if (w[i] > 0.0 || u[i] > 0.0) && sc > shift {
                shift = sc;
            }
        }
        shifts[b] = shift;
    }
    zacc.resize(nq * dim + nq, 0.0);
    for z in zacc.iter_mut() {
        *z = 0.0;
    }
    let (zrows, taus) = zacc.split_at_mut(nq * dim);
    // One pass over the packed slots: each V row is read once and folded
    // into every query's accumulator. Dead slots (w = u = 0) contribute
    // nothing and are skipped without touching their rows.
    for i in 0..n {
        let (wi, ui) = (w[i], u[i]);
        if wi <= 0.0 && ui <= 0.0 {
            continue;
        }
        let vrow = &values[i * dim..(i + 1) * dim];
        for b in 0..nq {
            if !shifts[b].is_finite() {
                continue;
            }
            let e = ((hist[i * nq + b] - shifts[b]) as f64).exp();
            if wi > 0.0 {
                let we = wi as f64 * e;
                for (zj, &vj) in zrows[b * dim..(b + 1) * dim].iter_mut().zip(vrow) {
                    *zj += we * vj as f64;
                }
            }
            if ui > 0.0 {
                taus[b] += ui as f64 * e;
            }
        }
    }
    for b in 0..nq {
        if !shifts[b].is_finite() {
            continue;
        }
        if let Some((_, v_new)) = extra {
            let e = ((extra_scores[b] - shifts[b]) as f64).exp();
            let zb = &mut zrows[b * dim..(b + 1) * dim];
            for (zj, &vj) in zb.iter_mut().zip(&v_new[b * dim..(b + 1) * dim]) {
                *zj += e * vj as f64;
            }
            taus[b] += e;
        }
        if taus[b] > 0.0 {
            let ob = &mut out[b * dim..(b + 1) * dim];
            for (o, &zj) in ob.iter_mut().zip(&zrows[b * dim..(b + 1) * dim]) {
                *o = (zj / taus[b]) as f32;
            }
        }
    }
}

/// [`attention_flat_into`] over **encoded** K/V views — the one
/// estimator entry point once arenas may be f16/int8. The `F32`/`F32`
/// arm delegates straight to [`attention_flat_into`], so every f32 path
/// stays bit-identical to the pre-encoding code; encoded arms run the
/// same algorithm with the fused dequantize-and-score sweep
/// ([`scores_batch_encoded_into`]) and per-slot register decode of V
/// rows — no f32 copy of an encoded arena is materialized. The `extra`
/// (new-token) slot is always raw f32: it is the decode step's own
/// K/V, which never lives in an encoded arena.
#[allow(clippy::too_many_arguments)]
pub fn attention_encoded_into(
    keys: KvSlice<'_>,
    values: KvSlice<'_>,
    w: &[f32],
    u: &[f32],
    dim: usize,
    qs: &[f32],
    nq: usize,
    extra: Option<(&[f32], &[f32])>,
    scores: &mut Vec<f32>,
    zacc: &mut Vec<f64>,
    out: &mut [f32],
) {
    if let (KvSlice::F32(k), KvSlice::F32(v)) = (keys, values) {
        return attention_flat_into(k, v, w, u, dim, qs, nq, extra, scores, zacc, out);
    }
    let n = w.len();
    debug_assert_eq!(keys.elems(), n * dim, "keys must be n × dim");
    debug_assert_eq!(values.elems(), n * dim, "values must be n × dim");
    debug_assert_eq!(u.len(), n, "w/u length mismatch");
    assert_eq!(qs.len(), nq * dim, "qs must be nq × dim");
    assert_eq!(out.len(), nq * dim, "out must be nq × dim");
    if let Some((k_new, v_new)) = extra {
        assert_eq!(k_new.len(), nq * dim, "extra keys must be nq × dim");
        assert_eq!(v_new.len(), nq * dim, "extra values must be nq × dim");
    }
    for o in out.iter_mut() {
        *o = 0.0;
    }
    if (n == 0 && extra.is_none()) || nq == 0 {
        return;
    }
    // Scratch layout: as in `attention_flat_into`, plus one dim-wide
    // region at the tail where each live V row is decoded while hot.
    scores.resize(n * nq + 2 * nq + dim, 0.0);
    let (hist, tail) = scores.split_at_mut(n * nq);
    let (extra_scores, tail) = tail.split_at_mut(nq);
    let (shifts, vbuf) = tail.split_at_mut(nq);
    scores_batch_encoded_into(keys, dim, qs, nq, hist);
    for b in 0..nq {
        let q = &qs[b * dim..(b + 1) * dim];
        extra_scores[b] = match extra {
            Some((k_new, _)) => dot(&k_new[b * dim..(b + 1) * dim], q),
            None => f32::NEG_INFINITY,
        };
        let mut shift = extra_scores[b];
        for i in 0..n {
            let sc = hist[i * nq + b];
            if (w[i] > 0.0 || u[i] > 0.0) && sc > shift {
                shift = sc;
            }
        }
        shifts[b] = shift;
    }
    zacc.resize(nq * dim + nq, 0.0);
    for z in zacc.iter_mut() {
        *z = 0.0;
    }
    let (zrows, taus) = zacc.split_at_mut(nq * dim);
    // One pass over the packed slots: each live V row is decoded once
    // into `vbuf` and folded into every query's accumulator. Dead slots
    // (w = u = 0) are skipped without touching their rows.
    for i in 0..n {
        let (wi, ui) = (w[i], u[i]);
        if wi <= 0.0 && ui <= 0.0 {
            continue;
        }
        values.decode_row_into(i, vbuf);
        for b in 0..nq {
            if !shifts[b].is_finite() {
                continue;
            }
            let e = ((hist[i * nq + b] - shifts[b]) as f64).exp();
            if wi > 0.0 {
                let we = wi as f64 * e;
                for (zj, &vj) in zrows[b * dim..(b + 1) * dim].iter_mut().zip(vbuf.iter()) {
                    *zj += we * vj as f64;
                }
            }
            if ui > 0.0 {
                taus[b] += ui as f64 * e;
            }
        }
    }
    for b in 0..nq {
        if !shifts[b].is_finite() {
            continue;
        }
        if let Some((_, v_new)) = extra {
            let e = ((extra_scores[b] - shifts[b]) as f64).exp();
            let zb = &mut zrows[b * dim..(b + 1) * dim];
            for (zj, &vj) in zb.iter_mut().zip(&v_new[b * dim..(b + 1) * dim]) {
                *zj += e * vj as f64;
            }
            taus[b] += e;
        }
        if taus[b] > 0.0 {
            let ob = &mut out[b * dim..(b + 1) * dim];
            for (o, &zj) in ob.iter_mut().zip(&zrows[b * dim..(b + 1) * dim]) {
                *o = (zj / taus[b]) as f32;
            }
        }
    }
}

/// C-slot buffer: row-major K and V `[C, d]` arenas (f32 by default,
/// optionally f16/int8-encoded — see [`KvDtype`]), per-slot weights `w`
/// (value path) and `u` (normalizer path). Unused slots carry zero
/// weights so the kernel can always run at full capacity. Rows are
/// encoded once at push time; the estimator reads them through the
/// fused encoded kernels.
#[derive(Debug, Clone)]
pub struct PackedCache {
    dim: usize,
    capacity: usize,
    used: usize,
    keys: KvArena,
    values: KvArena,
    w: Vec<f32>,
    u: Vec<f32>,
}

impl PackedCache {
    /// Allocate an empty f32 buffer.
    pub fn new(dim: usize, capacity: usize) -> Self {
        Self::new_encoded(dim, capacity, KvDtype::F32)
    }

    /// Allocate an empty buffer with the given K/V arena encoding.
    pub fn new_encoded(dim: usize, capacity: usize, enc: KvDtype) -> Self {
        assert!(dim > 0 && capacity > 0);
        Self {
            dim,
            capacity,
            used: 0,
            keys: KvArena::new(enc, capacity, dim),
            values: KvArena::new(enc, capacity, dim),
            w: vec![0.0; capacity],
            u: vec![0.0; capacity],
        }
    }

    /// K/V arena encoding.
    #[inline]
    pub fn dtype(&self) -> KvDtype {
        self.keys.dtype()
    }

    /// Reset to empty without reallocating.
    pub fn clear(&mut self) {
        self.used = 0;
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.u.iter_mut().for_each(|x| *x = 0.0);
        // K/V contents of unused slots are irrelevant: weights are zero.
    }

    /// Append one slot. Panics when full (policies must size buffers via
    /// `packed_slots`).
    pub fn push(&mut self, k: &[f32], v: &[f32], w: f32, u: f32) {
        assert!(self.used < self.capacity, "packed cache overflow");
        assert_eq!(k.len(), self.dim);
        assert_eq!(v.len(), self.dim);
        self.keys.write_row(self.used, k);
        self.values.write_row(self.used, v);
        self.w[self.used] = w;
        self.u[self.used] = u;
        self.used += 1;
    }

    /// Ensure a reusable scratch slot holds a buffer with at least
    /// `slots` capacity for `dim`-wide rows, rebuilding with ~50%
    /// headroom when it doesn't (so steadily growing packings don't
    /// rebuild every call); returns the buffer. This is the one
    /// growth policy for all batched-attention scratch buffers.
    pub fn ensure_scratch(
        slot: &mut Option<PackedCache>,
        dim: usize,
        slots: usize,
        enc: KvDtype,
    ) -> &mut PackedCache {
        let needed = slots.max(1);
        let rebuild = match slot {
            Some(buf) => buf.capacity < needed || buf.dim != dim || buf.dtype() != enc,
            None => true,
        };
        if rebuild {
            *slot = Some(PackedCache::new_encoded(dim, grown_capacity(slots), enc));
        }
        slot.as_mut().expect("scratch just ensured")
    }

    /// In-place variant of [`PackedCache::ensure_scratch`] for a
    /// non-optional scratch field: grow (with the same headroom
    /// policy) when `slots` no longer fit. Contents are reset; the
    /// arena encoding is preserved.
    pub fn ensure_capacity(&mut self, slots: usize) {
        if self.capacity < slots.max(1) {
            *self = PackedCache::new_encoded(self.dim, grown_capacity(slots), self.dtype());
        }
    }

    /// Append a normalizer-only slot: key + `u` weight, zero value row
    /// and zero `w` — without the caller having to materialize a zero
    /// value vector.
    pub fn push_normalizer(&mut self, k: &[f32], u: f32) {
        assert!(self.used < self.capacity, "packed cache overflow");
        assert_eq!(k.len(), self.dim);
        self.keys.write_row(self.used, k);
        self.values.zero_row(self.used);
        self.w[self.used] = 0.0;
        self.u[self.used] = u;
        self.used += 1;
    }

    /// Occupied slots.
    #[inline]
    pub fn used(&self) -> usize {
        self.used
    }

    /// Allocated slots.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Embedding dim.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Full K buffer `[capacity, dim]` row-major (zero-weighted tail
    /// included) — exactly what the XLA executable consumes. F32-only
    /// accessor (panics on encoded buffers): encoded readers go through
    /// [`PackedCache::keys_arena`].
    pub fn keys_buffer(&self) -> &[f32] {
        self.keys.f32()
    }

    /// Full V buffer. F32-only, like [`PackedCache::keys_buffer`].
    pub fn values_buffer(&self) -> &[f32] {
        self.values.f32()
    }

    /// Encoded K arena (`[capacity, dim]` rows; slots ≥ `used` hold the
    /// canonical zero row).
    pub fn keys_arena(&self) -> &KvArena {
        &self.keys
    }

    /// Encoded V arena.
    pub fn values_arena(&self) -> &KvArena {
        &self.values
    }

    /// Value-path weights.
    pub fn w_buffer(&self) -> &[f32] {
        &self.w
    }

    /// Normalizer-path weights.
    pub fn u_buffer(&self) -> &[f32] {
        &self.u
    }

    /// Key row of slot `i` (F32-only accessor, like
    /// [`PackedCache::keys_buffer`]).
    pub fn key(&self, i: usize) -> &[f32] {
        &self.keys.f32()[i * self.dim..(i + 1) * self.dim]
    }

    /// Value row of slot `i` (F32-only).
    pub fn value(&self, i: usize) -> &[f32] {
        &self.values.f32()[i * self.dim..(i + 1) * self.dim]
    }

    /// Evaluate the weighted-exponential attention estimator over the
    /// buffer (host reference for the L1 kernel; numerically stabilized
    /// with a max-shift over slots with positive weight). Delegates to
    /// [`PackedCache::attention_batch_into`] with a batch of one so
    /// there is exactly one estimator implementation.
    pub fn attention(&self, q: &[f32]) -> Vec<f32> {
        assert_eq!(q.len(), self.dim);
        let mut out = vec![0.0f32; self.dim];
        let mut scores = Vec::new();
        let mut zacc = Vec::new();
        self.attention_batch_into(q, 1, &mut scores, &mut zacc, &mut out);
        out
    }

    /// Batched estimator evaluation: `nq = qs.len()/dim` queries
    /// (row-major) answered with **one** scoring sweep over the packed
    /// buffer — each slot's key is loaded once and scored against the
    /// whole batch while hot. Per-query results are identical to
    /// [`PackedCache::attention`]. Allocating wrapper over
    /// [`PackedCache::attention_batch_into`].
    pub fn attention_batch(&self, qs: &[f32], nq: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; nq * self.dim];
        let mut scores = Vec::new();
        let mut zacc = Vec::new();
        self.attention_batch_into(qs, nq, &mut scores, &mut zacc, &mut out);
        out
    }

    /// Batched estimator evaluation into caller-provided buffers.
    /// `scores` (f32, `used × nq`) and `zacc` (f64, `dim`) are scratch
    /// reused across calls — no allocation once warmed; `out` must be
    /// `nq × dim`. Delegates to [`attention_encoded_into`] over the
    /// used prefix of the owned arenas (bit-identical to
    /// [`attention_flat_into`] for f32 buffers).
    pub fn attention_batch_into(
        &self,
        qs: &[f32],
        nq: usize,
        scores: &mut Vec<f32>,
        zacc: &mut Vec<f64>,
        out: &mut [f32],
    ) {
        attention_encoded_into(
            self.keys.slice_rows(0, self.used),
            self.values.slice_rows(0, self.used),
            &self.w[..self.used],
            &self.u[..self.used],
            self.dim,
            qs,
            nq,
            None,
            scores,
            zacc,
            out,
        );
    }

    /// Log-space normalizer estimate over the buffer: log Σ u_i·e^{⟨q,k_i⟩}.
    pub fn log_partition(&self, q: &[f32]) -> f32 {
        let mut shift = f32::NEG_INFINITY;
        let mut scores = vec![0.0f32; self.used];
        crate::tensor::scores_batch_encoded_into(
            self.keys.slice_rows(0, self.used),
            self.dim,
            q,
            1,
            &mut scores,
        );
        for i in 0..self.used {
            if self.u[i] > 0.0 && scores[i] > shift {
                shift = scores[i];
            }
        }
        if !shift.is_finite() {
            return f32::NEG_INFINITY;
        }
        let mut s = 0.0f64;
        for i in 0..self.used {
            if self.u[i] > 0.0 {
                s += self.u[i] as f64 * ((scores[i] - shift) as f64).exp();
            }
        }
        shift + (s as f32).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::exact_attention;
    use crate::rng::Pcg64;
    use crate::tensor::Tensor;

    #[test]
    fn unit_weights_recover_softmax_attention() {
        let dim = 6;
        let n = 12;
        let mut rng = Pcg64::seed_from_u64(3);
        let keys = Tensor::randn(&mut rng, n, dim, 0.5);
        let values = Tensor::randn(&mut rng, n, dim, 1.0);
        let mut buf = PackedCache::new(dim, n);
        for i in 0..n {
            buf.push(keys.row(i), values.row(i), 1.0, 1.0);
        }
        let q = [0.2f32, -0.1, 0.3, 0.05, -0.2, 0.1];
        let got = buf.attention(&q);
        let want = exact_attention(&q, &keys, &values);
        assert!(crate::linalg::rel_err_vec(&got, &want) < 1e-5);
    }

    #[test]
    fn zero_weight_slots_ignored() {
        let dim = 4;
        let mut buf = PackedCache::new(dim, 4);
        buf.push(&[1.0; 4], &[1.0; 4], 1.0, 1.0);
        // Poison slot with huge key but zero weights.
        buf.push(&[100.0; 4], &[100.0; 4], 0.0, 0.0);
        let out = buf.attention(&[1.0; 4]);
        for &x in &out {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn split_w_u_slots_match_manual_estimator() {
        // Value slots (w only) and normalizer slots (u only) evaluated
        // against a hand computation.
        let dim = 2;
        let mut buf = PackedCache::new(dim, 3);
        buf.push(&[0.0, 0.0], &[2.0, 4.0], 0.5, 0.0); // value slot, e^0
        buf.push(&[0.0, 0.0], &[0.0, 0.0], 0.0, 2.0); // norm slot, e^0
        buf.push(&[f32::ln(2.0), 0.0], &[0.0, 0.0], 0.0, 1.0); // norm slot
        let q = [1.0, 0.0];
        // z = 0.5·1·(2,4) = (1,2); τ = 2·1 + 1·2 = 4 → (0.25, 0.5).
        let out = buf.attention(&q);
        assert!((out[0] - 0.25).abs() < 1e-5, "{out:?}");
        assert!((out[1] - 0.5).abs() < 1e-5, "{out:?}");
    }

    #[test]
    fn attention_batch_matches_single_query() {
        let dim = 6;
        let n = 24;
        let mut rng = Pcg64::seed_from_u64(9);
        let keys = Tensor::randn(&mut rng, n, dim, 0.5);
        let values = Tensor::randn(&mut rng, n, dim, 1.0);
        let mut buf = PackedCache::new(dim, n);
        for i in 0..n {
            // Mixed slot kinds: value-only, normalizer-only, both, dead.
            let (w, u) = match i % 4 {
                0 => (1.0, 1.0),
                1 => (0.7, 0.0),
                2 => (0.0, 1.3),
                _ => (0.0, 0.0),
            };
            buf.push(keys.row(i), values.row(i), w, u);
        }
        let nq = 5;
        let qs = Tensor::randn(&mut rng, nq, dim, 0.4);
        let got = buf.attention_batch(qs.as_slice(), nq);
        for b in 0..nq {
            let want = buf.attention(qs.row(b));
            assert_eq!(&got[b * dim..(b + 1) * dim], &want[..], "b={b}");
        }
    }

    #[test]
    fn extra_slot_equals_pushed_slot() {
        // The decode path's reserved new-token slot (extra) must be
        // bit-identical to physically pushing that slot with w = u = 1.
        let dim = 5;
        let n = 10;
        let mut rng = Pcg64::seed_from_u64(21);
        let keys = Tensor::randn(&mut rng, n + 1, dim, 0.5);
        let values = Tensor::randn(&mut rng, n + 1, dim, 1.0);
        let mut with = PackedCache::new(dim, n + 1);
        let mut without = PackedCache::new(dim, n);
        for i in 0..n {
            let (w, u) = if i % 3 == 0 { (0.6, 0.0) } else { (1.0, 1.0) };
            with.push(keys.row(i), values.row(i), w, u);
            without.push(keys.row(i), values.row(i), w, u);
        }
        with.push(keys.row(n), values.row(n), 1.0, 1.0);
        let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.3).sin()).collect();
        let want = with.attention(&q);
        let mut out = vec![0.0f32; dim];
        let (mut scores, mut zacc) = (Vec::new(), Vec::new());
        attention_flat_into(
            &without.keys_buffer()[..n * dim],
            &without.values_buffer()[..n * dim],
            &without.w_buffer()[..n],
            &without.u_buffer()[..n],
            dim,
            &q,
            1,
            Some((keys.row(n), values.row(n))),
            &mut scores,
            &mut zacc,
            &mut out,
        );
        assert_eq!(out, want);
    }

    #[test]
    fn batched_queries_with_per_query_extras_match_single_calls() {
        // The cross-sequence decode path: nq queries over one shared
        // packed buffer, each carrying its own reserved new-token slot.
        // Every per-query result must be bit-identical to evaluating
        // that query alone with its own extra.
        let dim = 6;
        let n = 17;
        let nq = 4;
        let mut rng = Pcg64::seed_from_u64(33);
        let keys = Tensor::randn(&mut rng, n, dim, 0.5);
        let values = Tensor::randn(&mut rng, n, dim, 1.0);
        let mut buf = PackedCache::new(dim, n);
        for i in 0..n {
            // Mixed slot kinds, including dead slots.
            let (w, u) = match i % 4 {
                0 => (1.0, 1.0),
                1 => (0.7, 0.0),
                2 => (0.0, 1.3),
                _ => (0.0, 0.0),
            };
            buf.push(keys.row(i), values.row(i), w, u);
        }
        let qs = Tensor::randn(&mut rng, nq, dim, 0.4);
        let k_new = Tensor::randn(&mut rng, nq, dim, 0.5);
        let v_new = Tensor::randn(&mut rng, nq, dim, 1.0);
        let (kk, vv) = (&buf.keys_buffer()[..n * dim], &buf.values_buffer()[..n * dim]);
        let (ww, uu) = (&buf.w_buffer()[..n], &buf.u_buffer()[..n]);
        let mut batched = vec![0.0f32; nq * dim];
        let (mut scores, mut zacc) = (Vec::new(), Vec::new());
        attention_flat_into(
            kk,
            vv,
            ww,
            uu,
            dim,
            qs.as_slice(),
            nq,
            Some((k_new.as_slice(), v_new.as_slice())),
            &mut scores,
            &mut zacc,
            &mut batched,
        );
        for b in 0..nq {
            let mut single = vec![0.0f32; dim];
            attention_flat_into(
                kk,
                vv,
                ww,
                uu,
                dim,
                qs.row(b),
                1,
                Some((k_new.row(b), v_new.row(b))),
                &mut scores,
                &mut zacc,
                &mut single,
            );
            assert_eq!(&batched[b * dim..(b + 1) * dim], &single[..], "b={b}");
        }
    }

    #[test]
    fn extra_slot_over_empty_history_is_identity() {
        // Softmax over a single token returns that token's value.
        let dim = 3;
        let k_new = [0.4f32, -0.2, 0.1];
        let v_new = [2.0f32, -1.0, 0.5];
        let mut out = vec![0.0f32; dim];
        let (mut scores, mut zacc) = (Vec::new(), Vec::new());
        attention_flat_into(
            &[],
            &[],
            &[],
            &[],
            dim,
            &[0.1, 0.2, 0.3],
            1,
            Some((&k_new, &v_new)),
            &mut scores,
            &mut zacc,
            &mut out,
        );
        assert_eq!(out, v_new.to_vec());
    }

    #[test]
    fn scratch_growth_policy() {
        let mut slot: Option<PackedCache> = None;
        let buf = PackedCache::ensure_scratch(&mut slot, 4, 10, KvDtype::F32);
        assert!(buf.capacity() >= 10);
        assert_eq!(buf.dim(), 4);
        let cap = slot.as_ref().unwrap().capacity();
        // No rebuild while the request still fits.
        PackedCache::ensure_scratch(&mut slot, 4, cap, KvDtype::F32);
        assert_eq!(slot.as_ref().unwrap().capacity(), cap);
        // Dim change forces a rebuild.
        PackedCache::ensure_scratch(&mut slot, 8, 4, KvDtype::F32);
        assert_eq!(slot.as_ref().unwrap().dim(), 8);
        // Encoding change forces a rebuild too.
        PackedCache::ensure_scratch(&mut slot, 8, 4, KvDtype::Int8);
        assert_eq!(slot.as_ref().unwrap().dtype(), KvDtype::Int8);
        // In-place variant grows only when needed, keeping the dtype.
        let mut buf2 = PackedCache::new_encoded(2, 4, KvDtype::F16);
        buf2.ensure_capacity(4);
        assert_eq!(buf2.capacity(), 4);
        buf2.ensure_capacity(5);
        assert!(buf2.capacity() >= 5);
        assert_eq!(buf2.dtype(), KvDtype::F16);
    }

    #[test]
    fn encoded_buffers_attend_within_tolerance_of_f32() {
        let dim = 8;
        let n = 40;
        let mut rng = Pcg64::seed_from_u64(29);
        let keys = Tensor::randn(&mut rng, n, dim, 0.4);
        let values = Tensor::randn(&mut rng, n, dim, 1.0);
        let q: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.4).cos() * 0.5).collect();
        let mut f32_buf = PackedCache::new(dim, n);
        for i in 0..n {
            let (w, u) = if i % 5 == 0 { (0.0, 1.2) } else { (1.0, 1.0) };
            f32_buf.push(keys.row(i), values.row(i), w, u);
        }
        let want = f32_buf.attention(&q);
        for enc in [KvDtype::F16, KvDtype::Int8] {
            let mut buf = PackedCache::new_encoded(dim, n, enc);
            assert_eq!(buf.dtype(), enc);
            for i in 0..n {
                let (w, u) = if i % 5 == 0 { (0.0, 1.2) } else { (1.0, 1.0) };
                buf.push(keys.row(i), values.row(i), w, u);
            }
            let got = buf.attention(&q);
            let err = crate::linalg::rel_err_vec(&got, &want);
            assert!(err <= enc.decode_tolerance(), "{enc:?}: err={err}");
            // The encoded log-partition agrees with f32 to the same bar.
            let (lp, lp32) = (buf.log_partition(&q), f32_buf.log_partition(&q));
            assert!((lp - lp32).abs() <= 0.1, "{enc:?}: {lp} vs {lp32}");
        }
    }

    #[test]
    fn f32_encoded_entry_point_is_bit_identical_to_flat() {
        let dim = 6;
        let n = 15;
        let mut rng = Pcg64::seed_from_u64(31);
        let keys = Tensor::randn(&mut rng, n, dim, 0.5);
        let values = Tensor::randn(&mut rng, n, dim, 1.0);
        let mut buf = PackedCache::new(dim, n);
        for i in 0..n {
            buf.push(keys.row(i), values.row(i), 1.0, 1.0);
        }
        let qs = Tensor::randn(&mut rng, 3, dim, 0.4);
        let (mut scores, mut zacc) = (Vec::new(), Vec::new());
        let mut a = vec![0.0f32; 3 * dim];
        let mut b = vec![0.0f32; 3 * dim];
        attention_encoded_into(
            buf.keys_arena().slice_rows(0, n),
            buf.values_arena().slice_rows(0, n),
            &buf.w_buffer()[..n],
            &buf.u_buffer()[..n],
            dim,
            qs.as_slice(),
            3,
            None,
            &mut scores,
            &mut zacc,
            &mut a,
        );
        attention_flat_into(
            &buf.keys_buffer()[..n * dim],
            &buf.values_buffer()[..n * dim],
            &buf.w_buffer()[..n],
            &buf.u_buffer()[..n],
            dim,
            qs.as_slice(),
            3,
            None,
            &mut scores,
            &mut zacc,
            &mut b,
        );
        assert_eq!(a, b);
    }

    #[test]
    fn push_normalizer_equals_zero_value_push() {
        let dim = 3;
        let mut a = PackedCache::new(dim, 2);
        let mut b = PackedCache::new(dim, 2);
        a.push(&[1.0, 2.0, 3.0], &[0.0; 3], 0.0, 2.5);
        b.push_normalizer(&[1.0, 2.0, 3.0], 2.5);
        assert_eq!(a.attention(&[0.5, 0.1, -0.2]), b.attention(&[0.5, 0.1, -0.2]));
        assert_eq!(a.used(), b.used());
        assert_eq!(a.u_buffer(), b.u_buffer());
    }

    #[test]
    fn clear_reuses_buffer() {
        let mut buf = PackedCache::new(2, 2);
        buf.push(&[1.0, 0.0], &[1.0, 1.0], 1.0, 1.0);
        buf.clear();
        assert_eq!(buf.used(), 0);
        assert_eq!(buf.attention(&[1.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn stable_under_huge_scores() {
        let dim = 2;
        let mut buf = PackedCache::new(dim, 2);
        buf.push(&[40.0, 0.0], &[1.0, 0.0], 1.0, 1.0);
        buf.push(&[39.0, 0.0], &[0.0, 1.0], 1.0, 1.0);
        let out = buf.attention(&[40.0, 0.0]); // scores 1600, 1560
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(out[0] > 0.99);
        let lp = buf.log_partition(&[40.0, 0.0]);
        assert!((lp - 1600.0).abs() < 1.0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut buf = PackedCache::new(2, 1);
        buf.push(&[0.0; 2], &[0.0; 2], 1.0, 1.0);
        buf.push(&[0.0; 2], &[0.0; 2], 1.0, 1.0);
    }
}
