//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the serving hot path.
//!
//! Wraps the PJRT surface (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`)
//! through [`crate::xla`] — the vendored host stand-in for the external
//! `xla` crate, which keeps this crate building without the native
//! library (compilation of artifacts fails loudly until the real crate
//! is linked). One [`Runtime`] owns the client and a registry of
//! compiled executables keyed by their manifest name; python never runs
//! here.

mod literal;

pub use literal::{lit_f32, lit_i32, lit_i32_scalar, to_vec_f32};

use crate::io::Manifest;
use crate::xla;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// Compiled-executable registry over a PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: Manifest,
}

impl Runtime {
    /// Load the manifest from `artifacts_dir` and compile the named
    /// executables (pass `None` to compile everything listed).
    pub fn load(artifacts_dir: &Path, names: Option<&[&str]>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut rt = Runtime { client, executables: HashMap::new(), manifest };
        match names {
            Some(list) => {
                for name in list {
                    rt.compile_artifact(name)?;
                }
            }
            None => {
                for name in rt.manifest_artifact_names() {
                    rt.compile_artifact(&name)?;
                }
            }
        }
        Ok(rt)
    }

    /// Artifact names listed in the manifest (excluding the checkpoint).
    pub fn manifest_artifact_names(&self) -> Vec<String> {
        let mut names = vec!["prefill".to_string(), "attn_kernel".to_string()];
        let variants = self.manifest.str_or("model", "cache_variants", "");
        for c in variants.split(',').filter(|s| !s.is_empty()) {
            names.push(format!("decode_c{}", c.trim()));
        }
        let b = self.manifest.int_or("model", "decode_batch", 0);
        if b > 0 {
            if let Some(c) = variants.split(',').next() {
                names.push(format!("decode_b{b}_c{}", c.trim()));
            }
        }
        names.retain(|n| self.manifest.hlo_path(n).is_ok());
        names
    }

    /// Compile one artifact by manifest name (idempotent).
    pub fn compile_artifact(&mut self, name: &str) -> Result<()> {
        if self.executables.contains_key(name) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        self.executables.insert(name.to_string(), exe);
        eprintln!("[runtime] compiled {name} in {:?}", t0.elapsed());
        Ok(())
    }

    /// Execute a compiled artifact; returns the flattened tuple outputs.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name:?} not compiled"))?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(lit.to_tuple()?)
    }

    /// The underlying manifest (model hyperparameters etc.).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if an artifact is compiled.
    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
