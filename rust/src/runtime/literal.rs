//! Literal packing helpers: rust slices ⇄ XLA literals.

use crate::xla;
use anyhow::{Context, Result};

/// Build an f32 literal of the given shape from a flat row-major slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(numel == data.len(), "lit_f32: {dims:?} vs len {}", data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .context("creating f32 literal")
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let numel: usize = dims.iter().product();
    anyhow::ensure!(numel == data.len(), "lit_i32: {dims:?} vs len {}", data.len());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .context("creating i32 literal")
}

/// Scalar i32 literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::from(v)
}

/// Extract an f32 literal into a Vec.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal to f32 vec")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = [1.0f32, -2.5, 3.25, 0.0, 7.5, -0.125];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn i32_roundtrip() {
        let data = [5i32, -7, 0, 123];
        let lit = lit_i32(&data, &[4]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let lit = lit_i32_scalar(42);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![42]);
    }
}
