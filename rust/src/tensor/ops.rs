//! Matrix products on [`Tensor`].

use super::{dot, Tensor};

/// `C = A · B` (naive triple loop with the inner loop vectorized; host-side
/// matmuls here are small — the big ones run inside XLA).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols(), b.rows(), "matmul inner dims");
    let bt = b.transpose();
    let mut c = Tensor::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            c.set(i, j, dot(a.row(i), bt.row(j)));
        }
    }
    c
}

/// `y = A · x` for a vector `x` (delegates to the blocked
/// [`super::matvec_into`] kernel).
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), x.len(), "matvec inner dims");
    let mut out = vec![0.0f32; a.rows()];
    super::matvec_into(a.as_slice(), a.cols(), x, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(vec![1., 2., 3., 4.], 2, 2);
        let b = Tensor::from_vec(vec![1., 1., 1., 1.], 2, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.as_slice(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matvec_known() {
        let a = Tensor::from_vec(vec![1., 0., 0., 2.], 2, 2);
        assert_eq!(matvec(&a, &[3., 4.]), vec![3., 8.]);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_panics() {
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(2, 2);
        let _ = matmul(&a, &b);
    }
}
