//! Dense row-major 2-D tensor.

use crate::rng::{fill_gaussian, Rng};

/// A dense row-major matrix of `f32` (1-D tensors are `rows == 1`).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from an existing buffer; `data.len()` must equal `rows*cols`.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { data, rows, cols }
    }

    /// Empty (0-row) tensor with backing storage preallocated for
    /// `rows` rows — streams that `push_row` up to that many rows never
    /// reallocate.
    pub fn with_row_capacity(rows: usize, cols: usize) -> Self {
        Self { data: Vec::with_capacity(rows * cols), rows: 0, cols }
    }

    /// Ensure capacity for `additional` more rows beyond the current
    /// row count (single allocation; see [`Tensor::push_row`]).
    pub fn reserve_rows(&mut self, additional: usize) {
        self.data.reserve(additional * self.cols);
    }

    /// Rows currently representable without reallocation.
    pub fn row_capacity(&self) -> usize {
        if self.cols == 0 {
            usize::MAX
        } else {
            self.data.capacity() / self.cols
        }
    }

    /// Drop rows from the end, keeping `rows` (no-op when already
    /// shorter). Capacity is retained for reuse.
    pub fn truncate_rows(&mut self, rows: usize) {
        if rows < self.rows {
            self.data.truncate(rows * self.cols);
            self.rows = rows;
        }
    }

    /// I.i.d. gaussian entries with the given std.
    pub fn randn<R: Rng>(rng: &mut R, rows: usize, cols: usize, std: f32) -> Self {
        let mut t = Self::zeros(rows, cols);
        fill_gaussian(rng, &mut t.data, std);
        t
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The whole backing buffer, row-major.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable backing buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    /// Element assignment.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Append a row (grows the tensor by one row).
    ///
    /// Growth is explicitly amortized: when the backing buffer is full
    /// it doubles (with a small floor), so streaming 100k-row builds in
    /// the benches cost O(n) total copying instead of trusting the
    /// allocator's growth policy at every push.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.cols, "row width mismatch");
        let need = self.data.len() + self.cols;
        if need > self.data.capacity() {
            let target = need.max(self.data.capacity() * 2).max(8 * self.cols.max(1));
            self.data.reserve_exact(target - self.data.len());
        }
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Overwrite row `i` from a slice.
    #[inline]
    pub fn set_row(&mut self, i: usize, row: &[f32]) {
        self.row_mut(i).copy_from_slice(row);
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        super::dot(&self.data, &self.data).sqrt()
    }

    /// Operator (spectral) norm via power iteration; adequate for the
    /// error-bound checks in tests (‖V‖_op in Eq. 3 of the paper).
    pub fn op_norm(&self, iters: usize) -> f32 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        // Power-iterate on AᵀA with a deterministic start vector.
        let mut v = vec![1.0f32; self.cols];
        let inv = 1.0 / (self.cols as f32).sqrt();
        super::scale(&mut v, inv);
        let mut av = vec![0.0f32; self.rows];
        for _ in 0..iters.max(1) {
            // av = A v
            for i in 0..self.rows {
                av[i] = super::dot(self.row(i), &v);
            }
            // v = Aᵀ av
            for x in v.iter_mut() {
                *x = 0.0;
            }
            for i in 0..self.rows {
                super::axpy(av[i], self.row(i), &mut v);
            }
            let n = super::norm2(&v);
            if n == 0.0 {
                return 0.0;
            }
            super::scale(&mut v, 1.0 / n);
        }
        for i in 0..self.rows {
            av[i] = super::dot(self.row(i), &v);
        }
        super::norm2(&av)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn shape_and_access() {
        let mut t = Tensor::zeros(2, 3);
        t.set(1, 2, 5.0);
        assert_eq!(t.get(1, 2), 5.0);
        assert_eq!(t.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
    }

    #[test]
    fn push_row_grows() {
        let mut t = Tensor::zeros(0, 2);
        t.push_row(&[1.0, 2.0]);
        t.push_row(&[3.0, 4.0]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn push_row_growth_is_amortized() {
        // Doubling growth: pushing n rows performs O(log n) allocations,
        // observable as capacity jumps rather than per-push tight fits.
        let mut t = Tensor::zeros(0, 4);
        t.push_row(&[0.0; 4]);
        assert!(t.row_capacity() >= 8, "floor capacity, got {}", t.row_capacity());
        let mut grows = 0;
        let mut last_cap = t.row_capacity();
        for i in 0..10_000 {
            t.push_row(&[i as f32; 4]);
            if t.row_capacity() != last_cap {
                grows += 1;
                last_cap = t.row_capacity();
            }
        }
        assert!(grows <= 14, "too many reallocations: {grows}");
        assert_eq!(t.rows(), 10_001);
    }

    #[test]
    fn row_capacity_prealloc_and_truncate() {
        let mut t = Tensor::with_row_capacity(64, 3);
        assert_eq!(t.rows(), 0);
        assert!(t.row_capacity() >= 64);
        for i in 0..64 {
            t.push_row(&[i as f32; 3]);
        }
        t.set_row(5, &[9.0, 9.0, 9.0]);
        assert_eq!(t.row(5), &[9.0, 9.0, 9.0]);
        t.truncate_rows(10);
        assert_eq!(t.rows(), 10);
        assert!(t.row_capacity() >= 64, "truncate must keep capacity");
        t.reserve_rows(128);
        assert!(t.row_capacity() >= 138);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::from_vec(vec![1., 2., 3., 4., 5., 6.], 2, 3);
        let tt = t.transpose().transpose();
        assert_eq!(t, tt);
        assert_eq!(t.transpose().get(2, 1), 6.0);
    }

    #[test]
    fn op_norm_diagonal() {
        // diag(3, 1) has operator norm 3.
        let t = Tensor::from_vec(vec![3.0, 0.0, 0.0, 1.0], 2, 2);
        let n = t.op_norm(50);
        assert!((n - 3.0).abs() < 1e-3, "n={n}");
    }

    #[test]
    fn op_norm_bounds_fro() {
        let mut rng = Pcg64::seed_from_u64(2);
        let t = Tensor::randn(&mut rng, 8, 5, 1.0);
        let op = t.op_norm(100);
        let fro = t.fro_norm();
        assert!(op <= fro + 1e-4);
        assert!(op >= fro / (5.0f32).sqrt() - 1e-4);
    }
}
