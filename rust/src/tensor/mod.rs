//! Minimal row-major f32 tensor used on the rust side of the stack.
//!
//! The heavy math (model forward) runs inside XLA via the PJRT runtime;
//! this module covers the *host-side* numerics the coordinator needs on
//! the decode path: packing cache buffers, distances for clustering,
//! norms for reservoir sampling, and reference attention for tests.
//!
//! Deliberately small: no broadcasting, no autograd — dense row-major
//! `f32` with explicit shapes, tuned for predictable performance in the
//! L3 hot loop. The one storage-dtype exception is the KV-arena
//! encoding layer ([`KvDtype`]/[`KvArena`]/[`KvSlice`] in
//! [`encoding`]): KV rows may be stored f16 or per-row-affine int8, and
//! the fused kernels ([`scores_batch_encoded_into`],
//! [`matvec_batch_encoded_into`]) decompress rows into registers during
//! the sweep instead of materializing f32 copies.

mod dense;
mod encoding;
mod kernels;
mod ops;

pub use dense::Tensor;
pub use encoding::{f16_bits_to_f32, f32_to_f16_bits, KvArena, KvDtype, KvSlice};
pub use kernels::{
    axpy_rows_f64, matvec_batch_encoded_into, matvec_batch_into, matvec_into, nearest_row,
    scores_batch_encoded_into, scores_batch_into, scores_max_into, strided_max_into,
};
pub use ops::{matmul, matvec};

/// L2 norm of a vector.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Squared L2 norm of a vector.
#[inline]
pub fn norm2_sq(x: &[f32]) -> f32 {
    dot(x, x)
}

/// Dot product, manually unrolled 4-wide so LLVM reliably vectorizes it.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Squared euclidean distance between two vectors.
#[inline]
pub fn dist_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Euclidean distance.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f32 {
    dist_sq(a, b).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha` in place.
#[inline]
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// In-place numerically stable softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for xi in x.iter_mut() {
        *xi = (*xi - m).exp();
        z += *xi;
    }
    if z > 0.0 {
        let inv = 1.0 / z;
        for xi in x.iter_mut() {
            *xi *= inv;
        }
    }
}

/// Index of the maximum element (first on ties). Panics on empty input.
pub fn argmax(x: &[f32]) -> usize {
    assert!(!x.is_empty(), "argmax of empty slice");
    let mut best = 0;
    for (i, &v) in x.iter().enumerate() {
        if v > x[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 - 18.0) * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dist_consistency() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 6.0, 3.0];
        assert!((dist(&a, &b) - 5.0).abs() < 1e-6);
        assert!((dist_sq(&a, &b) - 25.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0f32, 1001.0, 999.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn softmax_empty_noop() {
        let mut x: Vec<f32> = vec![];
        softmax_inplace(&mut x);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn axpy_scale() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, [6.0, 12.0]);
    }

    #[test]
    fn norms() {
        let v = [3.0f32, 4.0];
        assert!((norm2(&v) - 5.0).abs() < 1e-6);
        assert!((norm2_sq(&v) - 25.0).abs() < 1e-6);
    }
}
