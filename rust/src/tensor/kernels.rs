//! Blocked, SIMD-friendly kernels over flat row-major buffers.
//!
//! The SubGen query hot path is a handful of streaming sweeps over
//! contiguous arenas: score every stored row against one (or a batch
//! of) queries, reduce a max, and accumulate weighted rows. These
//! kernels express exactly those sweeps, allocation-free, over raw
//! `&[f32]` row-major data so the sketches, the packed cache and the
//! oracle all share one code path.
//!
//! Per-row reductions are performed by [`super::dot`] /
//! [`super::dist_sq`] (4-wide accumulator splits), so results are
//! bit-identical to the scalar per-row code they replace — only the row
//! loop is restructured (4-row unrolling for load reuse and ILP).

use super::{dist_sq, dot, f16_bits_to_f32, KvSlice};

/// Dot of an f16-encoded row against an f32 vector, decoding elements
/// in registers. Same 4-wide accumulator split as [`super::dot`], so
/// the result is bit-identical to decoding the row to f32 first and
/// calling `dot` — without the materialized copy.
#[inline]
fn dot_f16(a: &[u16], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += f16_bits_to_f32(a[j]) * b[j];
        s1 += f16_bits_to_f32(a[j + 1]) * b[j + 1];
        s2 += f16_bits_to_f32(a[j + 2]) * b[j + 2];
        s3 += f16_bits_to_f32(a[j + 3]) * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += f16_bits_to_f32(a[j]) * b[j];
    }
    s
}

/// Integer-code dot: `Σ_j (a_j as f32) · b_j` over a raw int8 plane
/// (the per-row affine correction is applied by the caller). 4-wide
/// accumulator split like [`super::dot`].
#[inline]
fn dot_i8(a: &[i8], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] as f32 * b[j];
        s1 += a[j + 1] as f32 * b[j + 1];
        s2 += a[j + 2] as f32 * b[j + 2];
        s3 += a[j + 3] as f32 * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] as f32 * b[j];
    }
    s
}

/// Per-query element sums for the int8 affine correction, computed once
/// per sweep (stack buffer for the common small-batch case).
struct QuerySums {
    buf: [f32; 16],
    vec: Vec<f32>,
    n: usize,
}

impl QuerySums {
    fn new(qs: &[f32], cols: usize, nq: usize) -> QuerySums {
        let mut out = QuerySums { buf: [0.0; 16], vec: Vec::new(), n: nq };
        if nq > 16 {
            out.vec = vec![0.0; nq];
        }
        for b in 0..nq {
            let s: f32 = qs[b * cols..(b + 1) * cols].iter().sum();
            if nq > 16 {
                out.vec[b] = s;
            } else {
                out.buf[b] = s;
            }
        }
        out
    }

    #[inline]
    fn get(&self) -> &[f32] {
        if self.n > 16 {
            &self.vec
        } else {
            &self.buf[..self.n]
        }
    }
}

/// `out[r] = ⟨row_r, x⟩` for every row of `data`; 4-row-unrolled so the
/// compiler can interleave the four dot reductions and reuse `x` loads.
///
/// `out.len()` defines the row count; `data` must hold exactly
/// `out.len() * cols` elements.
pub fn matvec_into(data: &[f32], cols: usize, x: &[f32], out: &mut [f32]) {
    let rows = out.len();
    debug_assert_eq!(data.len(), rows * cols, "matvec_into shape mismatch");
    debug_assert_eq!(x.len(), cols, "matvec_into vector width");
    let mut r = 0;
    while r + 4 <= rows {
        let base = r * cols;
        out[r] = dot(&data[base..base + cols], x);
        out[r + 1] = dot(&data[base + cols..base + 2 * cols], x);
        out[r + 2] = dot(&data[base + 2 * cols..base + 3 * cols], x);
        out[r + 3] = dot(&data[base + 3 * cols..base + 4 * cols], x);
        r += 4;
    }
    while r < rows {
        out[r] = dot(&data[r * cols..(r + 1) * cols], x);
        r += 1;
    }
}

/// Batched matvec over one shared matrix: `nb` input vectors (row-major
/// in `xs`) scored against every row of `data`, with
/// `out[b * rows + r] = ⟨row_r, x_b⟩` — the same per-vector layout (and
/// bit-identical results, same [`super::dot`]) as `nb` independent
/// [`matvec_into`] calls, but each matrix row is loaded once and scored
/// against the whole batch while hot, like [`scores_batch_into`]. This
/// is what lets the host executor's batched decode pay for each weight
/// row once per engine tick instead of once per sequence.
pub fn matvec_batch_into(data: &[f32], cols: usize, xs: &[f32], nb: usize, out: &mut [f32]) {
    debug_assert_eq!(xs.len(), nb * cols, "matvec_batch_into input shape");
    debug_assert_eq!(out.len() * cols, data.len() * nb, "matvec_batch_into out shape");
    if nb == 0 {
        return;
    }
    let rows = out.len() / nb;
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        for b in 0..nb {
            out[b * rows + r] = dot(row, &xs[b * cols..(b + 1) * cols]);
        }
    }
}

/// [`matvec_batch_into`] over an encoded arena view: the fused
/// dequantize-and-multiply sweep. The `F32` arm delegates to
/// [`matvec_batch_into`] (bit-identical to the pre-encoding path);
/// `f16`/`int8` rows are decompressed into registers during the scan —
/// no f32 copy of the arena is materialized. Layout matches
/// [`matvec_batch_into`]: `out[b * rows + r] = ⟨row_r, x_b⟩`.
///
/// For `int8` the per-row affine map `x = s·(q − z)` folds into the
/// reduction as `⟨row_r, x_b⟩ = s_r·(Σ_j q_j·x_bj − z_r·Σ_j x_bj)`, so
/// each row costs one integer-code dot plus two multiplies; the
/// per-query sums are computed once per sweep.
pub fn matvec_batch_encoded_into(
    data: KvSlice<'_>,
    cols: usize,
    xs: &[f32],
    nb: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xs.len(), nb * cols, "matvec_batch_encoded_into input shape");
    debug_assert_eq!(out.len() * cols, data.elems() * nb, "matvec_batch_encoded_into out shape");
    if nb == 0 {
        return;
    }
    let rows = out.len() / nb;
    match data {
        KvSlice::F32(d) => matvec_batch_into(d, cols, xs, nb, out),
        KvSlice::F16 { data, .. } => {
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                for b in 0..nb {
                    out[b * rows + r] = dot_f16(row, &xs[b * cols..(b + 1) * cols]);
                }
            }
        }
        KvSlice::Int8 { data, scale, zero, .. } => {
            let sums = QuerySums::new(xs, cols, nb);
            let sum_x = sums.get();
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                let (s, z) = (scale[r], zero[r]);
                for b in 0..nb {
                    let acc = dot_i8(row, &xs[b * cols..(b + 1) * cols]);
                    out[b * rows + r] = s * (acc - z * sum_x[b]);
                }
            }
        }
    }
}

/// Fused score+max pass: `out[r] = ⟨row_r, x⟩` and the maximum score is
/// reduced in the same sweep (no second pass over the buffer). Returns
/// `f32::NEG_INFINITY` when there are no rows.
pub fn scores_max_into(data: &[f32], cols: usize, x: &[f32], out: &mut [f32]) -> f32 {
    matvec_into(data, cols, x, out);
    let mut m = f32::NEG_INFINITY;
    for &sc in out.iter() {
        if sc > m {
            m = sc;
        }
    }
    m
}

/// Batched scores: `out[r * nq + b] = ⟨row_r, q_b⟩` with `qs` holding
/// `nq` queries row-major. One sweep over `data` serves every query —
/// each stored row is loaded once and scored against the whole batch
/// while hot, which is what makes `query_batch` amortize sketch memory
/// traffic.
pub fn scores_batch_into(data: &[f32], cols: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
    debug_assert_eq!(qs.len(), nq * cols, "scores_batch_into query shape");
    debug_assert_eq!(out.len() * cols, data.len() * nq, "scores_batch_into out shape");
    let rows = if cols == 0 { 0 } else { data.len() / cols };
    for r in 0..rows {
        let row = &data[r * cols..(r + 1) * cols];
        let out_row = &mut out[r * nq..(r + 1) * nq];
        let mut b = 0;
        while b + 2 <= nq {
            out_row[b] = dot(row, &qs[b * cols..(b + 1) * cols]);
            out_row[b + 1] = dot(row, &qs[(b + 1) * cols..(b + 2) * cols]);
            b += 2;
        }
        if b < nq {
            out_row[b] = dot(row, &qs[b * cols..(b + 1) * cols]);
        }
    }
}

/// [`scores_batch_into`] over an encoded arena view: the fused
/// dequantize-and-score sweep behind the attention kernel. The `F32`
/// arm delegates to [`scores_batch_into`] (bit-identical to the
/// pre-encoding path); encoded rows decode in registers during the
/// sweep. Layout matches [`scores_batch_into`]:
/// `out[r * nq + b] = ⟨row_r, q_b⟩`. See
/// [`matvec_batch_encoded_into`] for the int8 affine folding.
pub fn scores_batch_encoded_into(
    keys: KvSlice<'_>,
    cols: usize,
    qs: &[f32],
    nq: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(qs.len(), nq * cols, "scores_batch_encoded_into query shape");
    debug_assert_eq!(out.len() * cols, keys.elems() * nq, "scores_batch_encoded_into out shape");
    let rows = keys.rows(cols);
    match keys {
        KvSlice::F32(d) => scores_batch_into(d, cols, qs, nq, out),
        KvSlice::F16 { data, .. } => {
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                let out_row = &mut out[r * nq..(r + 1) * nq];
                for (b, o) in out_row.iter_mut().enumerate() {
                    *o = dot_f16(row, &qs[b * cols..(b + 1) * cols]);
                }
            }
        }
        KvSlice::Int8 { data, scale, zero, .. } => {
            let sums = QuerySums::new(qs, cols, nq);
            let sum_q = sums.get();
            for r in 0..rows {
                let row = &data[r * cols..(r + 1) * cols];
                let (s, z) = (scale[r], zero[r]);
                let out_row = &mut out[r * nq..(r + 1) * nq];
                for (b, o) in out_row.iter_mut().enumerate() {
                    let acc = dot_i8(row, &qs[b * cols..(b + 1) * cols]);
                    *o = s * (acc - z * sum_q[b]);
                }
            }
        }
    }
}

/// Column-strided max over a batched score buffer laid out as
/// `scores[r * nq + b]`: writes `max_r scores[r][b]` into `out[b]`
/// (`NEG_INFINITY` for empty row sets).
pub fn strided_max_into(scores: &[f32], nq: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), nq);
    for m in out.iter_mut() {
        *m = f32::NEG_INFINITY;
    }
    if nq == 0 {
        return;
    }
    for chunk in scores.chunks_exact(nq) {
        for (m, &sc) in out.iter_mut().zip(chunk) {
            if sc > *m {
                *m = sc;
            }
        }
    }
}

/// Allocation-free weighted row accumulation in f64:
/// `acc[j] += Σ_r w[r] · data[r][j]`. Rows with zero weight are
/// skipped without touching their data.
pub fn axpy_rows_f64(data: &[f32], cols: usize, w: &[f64], acc: &mut [f64]) {
    debug_assert_eq!(data.len(), w.len() * cols, "axpy_rows_f64 shape mismatch");
    debug_assert_eq!(acc.len(), cols, "axpy_rows_f64 accumulator width");
    for (r, &wr) in w.iter().enumerate() {
        if wr == 0.0 {
            continue;
        }
        let row = &data[r * cols..(r + 1) * cols];
        for (a, &v) in acc.iter_mut().zip(row) {
            *a += wr * v as f64;
        }
    }
}

/// Nearest row of `data` to `point` by squared euclidean distance
/// (first row wins ties, matching a sequential scan). Returns `None`
/// when there are no rows. Distances are computed four rows at a time;
/// the comparison order stays sequential so tie-breaking is identical
/// to the scalar loop this replaces.
pub fn nearest_row(data: &[f32], cols: usize, point: &[f32]) -> Option<(usize, f32)> {
    debug_assert_eq!(point.len(), cols);
    if cols == 0 || data.len() < cols {
        return None;
    }
    let rows = data.len() / cols;
    let mut best = 0usize;
    let mut best_d2 = f32::INFINITY;
    let mut r = 0;
    while r + 4 <= rows {
        let base = r * cols;
        let d = [
            dist_sq(&data[base..base + cols], point),
            dist_sq(&data[base + cols..base + 2 * cols], point),
            dist_sq(&data[base + 2 * cols..base + 3 * cols], point),
            dist_sq(&data[base + 3 * cols..base + 4 * cols], point),
        ];
        for (i, &d2) in d.iter().enumerate() {
            if d2 < best_d2 {
                best_d2 = d2;
                best = r + i;
            }
        }
        r += 4;
    }
    while r < rows {
        let d2 = dist_sq(&data[r * cols..(r + 1) * cols], point);
        if d2 < best_d2 {
            best_d2 = d2;
            best = r;
        }
        r += 1;
    }
    Some((best, best_d2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn random_flat(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian32(0.0, 1.0)).collect()
    }

    #[test]
    fn matvec_matches_per_row_dot() {
        let mut rng = Pcg64::seed_from_u64(1);
        for rows in [0usize, 1, 3, 4, 7, 16, 21] {
            let cols = 9;
            let data = random_flat(&mut rng, rows * cols);
            let x = random_flat(&mut rng, cols);
            let mut out = vec![0.0f32; rows];
            matvec_into(&data, cols, &x, &mut out);
            for r in 0..rows {
                let want = dot(&data[r * cols..(r + 1) * cols], &x);
                assert_eq!(out[r], want, "rows={rows} r={r}");
            }
        }
    }

    #[test]
    fn matvec_batch_matches_per_vector_matvec() {
        let mut rng = Pcg64::seed_from_u64(11);
        let (rows, cols) = (13, 7);
        let data = random_flat(&mut rng, rows * cols);
        for nb in [0usize, 1, 2, 5] {
            let xs = random_flat(&mut rng, nb * cols);
            let mut batched = vec![0.0f32; nb * rows];
            matvec_batch_into(&data, cols, &xs, nb, &mut batched);
            for b in 0..nb {
                let mut single = vec![0.0f32; rows];
                matvec_into(&data, cols, &xs[b * cols..(b + 1) * cols], &mut single);
                assert_eq!(&batched[b * rows..(b + 1) * rows], &single[..], "nb={nb} b={b}");
            }
        }
    }

    #[test]
    fn encoded_sweeps_match_decoded_reference() {
        use crate::tensor::{KvArena, KvDtype};
        let mut rng = Pcg64::seed_from_u64(23);
        let (rows, cols) = (19, 8);
        // nq = 17 exercises the heap fallback of the query-sum scratch.
        for nq in [1usize, 3, 17] {
            let qs = random_flat(&mut rng, nq * cols);
            for dtype in KvDtype::ALL {
                let mut arena = KvArena::new(dtype, rows, cols);
                for r in 0..rows {
                    let row = random_flat(&mut rng, cols);
                    arena.write_row(r, &row);
                }
                // Reference: decode the arena and run the f32 kernels.
                let decoded = arena.to_f32_vec();
                let mut want_scores = vec![0.0f32; rows * nq];
                scores_batch_into(&decoded, cols, &qs, nq, &mut want_scores);
                let mut got_scores = vec![0.0f32; rows * nq];
                scores_batch_encoded_into(arena.as_kv_slice(), cols, &qs, nq, &mut got_scores);
                let mut want_mv = vec![0.0f32; nq * rows];
                matvec_batch_into(&decoded, cols, &qs, nq, &mut want_mv);
                let mut got_mv = vec![0.0f32; nq * rows];
                matvec_batch_encoded_into(arena.as_kv_slice(), cols, &qs, nq, &mut got_mv);
                match dtype {
                    // f32 delegates and f16 decodes element-exact with
                    // the same accumulation order: bit-identical.
                    KvDtype::F32 | KvDtype::F16 => {
                        assert_eq!(got_scores, want_scores, "{dtype:?} nq={nq}");
                        assert_eq!(got_mv, want_mv, "{dtype:?} nq={nq}");
                    }
                    // int8's affine folding reorders the reduction, so
                    // allow f32 round-off against the decoded reference.
                    KvDtype::Int8 => {
                        for (g, w) in
                            got_scores.iter().zip(&want_scores).chain(got_mv.iter().zip(&want_mv))
                        {
                            assert!(
                                (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                                "{dtype:?} nq={nq}: {g} vs {w}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn scores_max_is_fused_max() {
        let mut rng = Pcg64::seed_from_u64(2);
        let (rows, cols) = (13, 5);
        let data = random_flat(&mut rng, rows * cols);
        let x = random_flat(&mut rng, cols);
        let mut out = vec![0.0f32; rows];
        let m = scores_max_into(&data, cols, &x, &mut out);
        let want = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert_eq!(m, want);
        let mut empty: [f32; 0] = [];
        assert_eq!(scores_max_into(&[], cols, &x, &mut empty), f32::NEG_INFINITY);
    }

    #[test]
    fn batch_scores_match_query_loop() {
        let mut rng = Pcg64::seed_from_u64(3);
        let (rows, cols, nq) = (11, 6, 5);
        let data = random_flat(&mut rng, rows * cols);
        let qs = random_flat(&mut rng, nq * cols);
        let mut batched = vec![0.0f32; rows * nq];
        scores_batch_into(&data, cols, &qs, nq, &mut batched);
        for b in 0..nq {
            let q = &qs[b * cols..(b + 1) * cols];
            let mut single = vec![0.0f32; rows];
            matvec_into(&data, cols, q, &mut single);
            for r in 0..rows {
                assert_eq!(batched[r * nq + b], single[r], "b={b} r={r}");
            }
        }
        let mut maxes = vec![0.0f32; nq];
        strided_max_into(&batched, nq, &mut maxes);
        for b in 0..nq {
            let want = (0..rows).map(|r| batched[r * nq + b]).fold(f32::NEG_INFINITY, f32::max);
            assert_eq!(maxes[b], want, "b={b}");
        }
    }

    #[test]
    fn axpy_rows_matches_naive() {
        let mut rng = Pcg64::seed_from_u64(4);
        let (rows, cols) = (9, 4);
        let data = random_flat(&mut rng, rows * cols);
        let w: Vec<f64> =
            (0..rows).map(|r| if r % 3 == 0 { 0.0 } else { r as f64 * 0.5 }).collect();
        let mut acc = vec![1.0f64; cols];
        axpy_rows_f64(&data, cols, &w, &mut acc);
        for j in 0..cols {
            let mut want = 1.0f64;
            for r in 0..rows {
                want += w[r] * data[r * cols + j] as f64;
            }
            assert!((acc[j] - want).abs() < 1e-12, "j={j}");
        }
    }

    #[test]
    fn nearest_row_matches_scan_with_ties() {
        let cols = 3;
        // Rows 1 and 3 are identical: the first must win.
        let data = vec![
            5.0, 5.0, 5.0, //
            1.0, 0.0, 0.0, //
            2.0, 2.0, 2.0, //
            1.0, 0.0, 0.0,
        ];
        let (idx, d2) = nearest_row(&data, cols, &[1.0, 0.0, 0.0]).unwrap();
        assert_eq!(idx, 1);
        assert_eq!(d2, 0.0);
        assert!(nearest_row(&[], cols, &[0.0; 3]).is_none());
        let mut rng = Pcg64::seed_from_u64(5);
        for rows in [1usize, 2, 5, 8, 13] {
            let data = random_flat(&mut rng, rows * cols);
            let p = random_flat(&mut rng, cols);
            let got = nearest_row(&data, cols, &p).unwrap();
            let mut best = (0usize, f32::INFINITY);
            for r in 0..rows {
                let d2 = dist_sq(&data[r * cols..(r + 1) * cols], &p);
                if d2 < best.1 {
                    best = (r, d2);
                }
            }
            assert_eq!(got, best, "rows={rows}");
        }
    }
}
