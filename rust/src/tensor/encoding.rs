//! The KvEncoding layer: storage dtypes for KV arenas behind the
//! kernel boundary.
//!
//! Decode is memory-bandwidth-bound on *bytes per retained row*, so on
//! top of SubGen's sublinear bound on *how many* rows we keep, a
//! compressed on-arena encoding is a direct speedup multiplier. This
//! module owns the three encodings ([`KvDtype`]), the encoded arena
//! ([`KvArena`]) and its borrowed view ([`KvSlice`]):
//!
//! * `f32` — the uncompressed baseline; every path through an `F32`
//!   arena is bit-identical to the pre-encoding code.
//! * `f16` — IEEE binary16 rows (round-to-nearest-even), 2 bytes/elem.
//! * `int8` — per-row affine quantization `x ≈ s·(q − z)` with
//!   `q ∈ [-128, 127]`, structure-of-arrays planes (a contiguous i8
//!   data plane plus separate f32 scale/zero planes, the
//!   fastlanes-style transposed-metadata layout), 1 byte/elem + 8
//!   bytes/row.
//!
//! Encoding happens once per row at write time ([`KvArena::write_row`])
//! and is deterministic, so incremental arena assembly produces the
//! same encoded bytes as from-scratch assembly. The fused sweeps in
//! [`crate::tensor`] (`scores_batch_encoded_into`,
//! `matvec_batch_encoded_into`) and the attention kernel decompress
//! rows into registers during the scan — no f32 copy of an encoded
//! arena is ever materialized on the hot path.
//!
//! Everything above the kvcache/tensor boundary (executors, the engine,
//! the router) stays encoding-blind: encodings travel as plain strings
//! in configs and as opaque [`KvSlice`] values through `head_slices`.

use anyhow::Result;

/// KV arena storage dtype. See the module docs for the encodings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvDtype {
    /// Uncompressed f32 rows (4 bytes/elem) — the bit-exact baseline.
    #[default]
    F32,
    /// IEEE binary16 rows (2 bytes/elem), round-to-nearest-even.
    F16,
    /// Per-row affine int8: `x ≈ scale·(q − zero)`, 1 byte/elem plus
    /// two f32s of per-row metadata.
    Int8,
}

impl KvDtype {
    /// All encodings, in serialization-index order.
    pub const ALL: [KvDtype; 3] = [KvDtype::F32, KvDtype::F16, KvDtype::Int8];

    /// Parse a config-facing name (`f32` | `f16` | `int8`).
    pub fn parse(name: &str) -> Result<KvDtype> {
        match name {
            "f32" => Ok(KvDtype::F32),
            "f16" => Ok(KvDtype::F16),
            "int8" => Ok(KvDtype::Int8),
            other => anyhow::bail!("unknown kv dtype {other:?} (expected f32|f16|int8)"),
        }
    }

    /// Config-facing name.
    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Int8 => "int8",
        }
    }

    /// Stable serialization tag (snapshot v4, flat-cache image v2).
    pub fn index(self) -> u64 {
        match self {
            KvDtype::F32 => 0,
            KvDtype::F16 => 1,
            KvDtype::Int8 => 2,
        }
    }

    /// Inverse of [`KvDtype::index`].
    pub fn from_index(i: u64) -> Result<KvDtype> {
        KvDtype::ALL
            .get(i as usize)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("bad kv dtype index {i}"))
    }

    /// Encoded bytes per `dim`-wide row (data plane plus any per-row
    /// metadata planes).
    pub fn row_bytes(self, dim: usize) -> usize {
        match self {
            KvDtype::F32 => 4 * dim,
            KvDtype::F16 => 2 * dim,
            KvDtype::Int8 => dim + 8,
        }
    }

    /// Relative-error tolerance bar for decode outputs versus the f32
    /// path — the bound the property tests and the accuracy harness
    /// hold every policy to.
    pub fn decode_tolerance(self) -> f32 {
        match self {
            KvDtype::F32 => 0.0,
            KvDtype::F16 => 5e-3,
            KvDtype::Int8 => 8e-2,
        }
    }
}

/// Convert f32 to IEEE binary16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = ((x >> 16) & 0x8000) as u16;
    let mut exp = ((x >> 23) & 0xFF) as i32;
    let man = x & 0x007F_FFFF;
    if exp == 255 {
        // Inf / NaN (NaNs quieten to a canonical payload).
        let m = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7C00 | m;
    }
    exp = exp - 127 + 15;
    if exp >= 31 {
        return sign | 0x7C00; // overflow → inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflows to zero even after rounding
        }
        // Subnormal: shift the (implicit-bit) mantissa into place.
        let man = man | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = 1u32 << (shift - 1);
        let rem = man & ((1u32 << shift) - 1);
        let mut m = man >> shift;
        if rem > half || (rem == half && (m & 1) == 1) {
            m += 1; // may carry into the exponent: smallest normal, still valid
        }
        return sign | m as u16;
    }
    let mut m = man >> 13;
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && (m & 1) == 1) {
        m += 1;
        if m == 0x400 {
            m = 0;
            exp += 1;
            if exp >= 31 {
                return sign | 0x7C00;
            }
        }
    }
    sign | ((exp as u16) << 10) | m as u16
}

/// Convert IEEE binary16 bits to f32 (exact — every f16 value is
/// representable in f32).
#[inline(always)]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1F;
    let man = (h & 0x03FF) as u32;
    if exp == 0 {
        // Zero / subnormal: man × 2⁻²⁴, sign applied bitwise.
        let v = man as f32 * f32::from_bits(0x3380_0000);
        return f32::from_bits(v.to_bits() | sign);
    }
    if exp == 31 {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    f32::from_bits(sign | ((exp as u32 + 112) << 23) | (man << 13))
}

/// Encode one `int8` row: returns `(scale, zero)` and fills `dst` with
/// the quantized codes. `x ≈ scale·(q − zero)`; constant rows (and zero
/// rows) decode exactly.
#[inline]
fn encode_row_i8(src: &[f32], dst: &mut [i8]) -> (f32, f32) {
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &x in src {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let span = hi - lo;
    let s = if span > 0.0 && span.is_finite() { span / 255.0 } else { 1.0 };
    let mut z = -lo / s - 128.0;
    if !z.is_finite() {
        z = 0.0;
    }
    for (q, &x) in dst.iter_mut().zip(src) {
        *q = (x / s + z).round().clamp(-128.0, 127.0) as i8;
    }
    (s, z)
}

/// Encoded row storage. Planes are structure-of-arrays so the data
/// plane streams contiguously during fused sweeps.
#[derive(Debug, Clone, PartialEq)]
enum Store {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Int8 { data: Vec<i8>, scale: Vec<f32>, zero: Vec<f32> },
}

/// A `rows × dim` row-major arena holding encoded K or V rows.
/// Rows are encoded once at [`KvArena::write_row`] time and read back
/// either fused (via [`KvSlice`] and the encoded kernels) or decoded
/// row-at-a-time ([`KvArena::decode_row_into`]).
#[derive(Debug, Clone, PartialEq)]
pub struct KvArena {
    dim: usize,
    rows: usize,
    store: Store,
}

impl KvArena {
    /// Allocate a zeroed arena (every row decodes to all-zero).
    pub fn new(dtype: KvDtype, rows: usize, dim: usize) -> KvArena {
        let store = match dtype {
            KvDtype::F32 => Store::F32(vec![0.0; rows * dim]),
            KvDtype::F16 => Store::F16(vec![0; rows * dim]),
            KvDtype::Int8 => Store::Int8 {
                data: vec![0; rows * dim],
                scale: vec![1.0; rows],
                zero: vec![0.0; rows],
            },
        };
        KvArena { dim, rows, store }
    }

    /// Storage dtype.
    pub fn dtype(&self) -> KvDtype {
        match &self.store {
            Store::F32(_) => KvDtype::F32,
            Store::F16(_) => KvDtype::F16,
            Store::Int8 { .. } => KvDtype::Int8,
        }
    }

    /// Row width.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Logical element count (`rows × dim`).
    #[inline]
    pub fn len(&self) -> usize {
        self.rows * self.dim
    }

    /// True when the arena holds no rows.
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Encode `src` (len `dim`) into row `row`. Deterministic: the same
    /// f32 row always produces the same encoded bytes, which is what
    /// makes incremental assembly byte-identical to full assembly.
    #[inline]
    pub fn write_row(&mut self, row: usize, src: &[f32]) {
        debug_assert_eq!(src.len(), self.dim);
        let at = row * self.dim;
        match &mut self.store {
            Store::F32(d) => d[at..at + src.len()].copy_from_slice(src),
            Store::F16(d) => {
                for (h, &x) in d[at..at + src.len()].iter_mut().zip(src) {
                    *h = f32_to_f16_bits(x);
                }
            }
            Store::Int8 { data, scale, zero } => {
                let (s, z) = encode_row_i8(src, &mut data[at..at + src.len()]);
                scale[row] = s;
                zero[row] = z;
            }
        }
    }

    /// Reset row `row` to the canonical zero encoding (decodes to 0.0).
    pub fn zero_row(&mut self, row: usize) {
        let at = row * self.dim;
        match &mut self.store {
            Store::F32(d) => d[at..at + self.dim].iter_mut().for_each(|x| *x = 0.0),
            Store::F16(d) => d[at..at + self.dim].iter_mut().for_each(|x| *x = 0),
            Store::Int8 { data, scale, zero } => {
                data[at..at + self.dim].iter_mut().for_each(|x| *x = 0);
                scale[row] = 1.0;
                zero[row] = 0.0;
            }
        }
    }

    /// Borrow rows `row0 .. row0 + n` as an encoded view.
    pub fn slice_rows(&self, row0: usize, n: usize) -> KvSlice<'_> {
        let at = row0 * self.dim;
        let end = (row0 + n) * self.dim;
        match &self.store {
            Store::F32(d) => KvSlice::F32(&d[at..end]),
            Store::F16(d) => KvSlice::F16 { data: &d[at..end], dim: self.dim },
            Store::Int8 { data, scale, zero } => KvSlice::Int8 {
                data: &data[at..end],
                scale: &scale[row0..row0 + n],
                zero: &zero[row0..row0 + n],
                dim: self.dim,
            },
        }
    }

    /// Borrow the whole arena as an encoded view.
    pub fn as_kv_slice(&self) -> KvSlice<'_> {
        self.slice_rows(0, self.rows)
    }

    /// Copy `n` encoded rows from `src` (same dtype and dim) starting
    /// at `src_row` into `self` starting at `dst_row` — a plane-wise
    /// memcpy, no decode/re-encode.
    pub fn copy_rows_from(&mut self, src: &KvArena, src_row: usize, dst_row: usize, n: usize) {
        assert_eq!(self.dim, src.dim, "copy_rows_from: dim mismatch");
        let (sa, da) = (src_row * self.dim, dst_row * self.dim);
        let len = n * self.dim;
        match (&mut self.store, &src.store) {
            (Store::F32(d), Store::F32(s)) => d[da..da + len].copy_from_slice(&s[sa..sa + len]),
            (Store::F16(d), Store::F16(s)) => d[da..da + len].copy_from_slice(&s[sa..sa + len]),
            (
                Store::Int8 { data, scale, zero },
                Store::Int8 { data: sd, scale: ss, zero: sz },
            ) => {
                data[da..da + len].copy_from_slice(&sd[sa..sa + len]);
                scale[dst_row..dst_row + n].copy_from_slice(&ss[src_row..src_row + n]);
                zero[dst_row..dst_row + n].copy_from_slice(&sz[src_row..src_row + n]);
            }
            _ => panic!("copy_rows_from: dtype mismatch ({:?} <- {:?})", self.dtype(), src.dtype()),
        }
    }

    /// Decode row `row` into `out` (len `dim`).
    pub fn decode_row_into(&self, row: usize, out: &mut [f32]) {
        self.as_kv_slice().decode_row_into(row, out);
    }

    /// Borrow the raw f32 plane. Panics unless the arena is `F32` —
    /// callers on the always-f32 paths (the chunked-prefill carry) use
    /// this; encoded arenas must go through [`KvSlice`].
    #[track_caller]
    pub fn f32(&self) -> &[f32] {
        match &self.store {
            Store::F32(d) => d,
            _ => panic!("KvArena::f32 on {} arena", self.dtype().name()),
        }
    }

    /// Mutable form of [`KvArena::f32`]; same F32-only contract.
    #[track_caller]
    pub fn f32_mut(&mut self) -> &mut [f32] {
        match &mut self.store {
            Store::F32(d) => d,
            _ => panic!("KvArena::f32_mut on {} arena", self.dtype().name()),
        }
    }

    /// Decode the whole arena to a fresh f32 vector (cold paths only:
    /// XLA literal upload, tests).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len()];
        for r in 0..self.rows {
            let at = r * self.dim;
            self.decode_row_into(r, &mut out[at..at + self.dim]);
        }
        out
    }

    /// Encoded byte length of [`KvArena::write_bytes`]'s output.
    pub fn byte_len(&self) -> usize {
        self.rows * self.dtype().row_bytes(self.dim)
    }

    /// Append the arena's encoded planes to `out` (LE, bit-exact):
    /// the data plane first, then any per-row metadata planes.
    pub fn write_bytes(&self, out: &mut Vec<u8>) {
        match &self.store {
            Store::F32(d) => {
                for x in d {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            Store::F16(d) => {
                for h in d {
                    out.extend_from_slice(&h.to_le_bytes());
                }
            }
            Store::Int8 { data, scale, zero } => {
                for q in data {
                    out.push(*q as u8);
                }
                for x in scale.iter().chain(zero.iter()) {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    /// Rebuild an arena from [`KvArena::write_bytes`] output —
    /// bit-identical planes (the round-trip preserves every encoded
    /// byte, NaN payloads included).
    pub fn from_bytes(dtype: KvDtype, rows: usize, dim: usize, bytes: &[u8]) -> Result<KvArena> {
        let want = rows * dtype.row_bytes(dim);
        anyhow::ensure!(bytes.len() == want, "kv arena image: {} != {want} bytes", bytes.len());
        let n = rows * dim;
        let read_f32s = |at: usize, count: usize| -> Vec<f32> {
            (0..count)
                .map(|i| {
                    f32::from_le_bytes(bytes[at + i * 4..at + (i + 1) * 4].try_into().unwrap())
                })
                .collect()
        };
        let store = match dtype {
            KvDtype::F32 => Store::F32(read_f32s(0, n)),
            KvDtype::F16 => Store::F16(
                (0..n)
                    .map(|i| u16::from_le_bytes(bytes[i * 2..(i + 1) * 2].try_into().unwrap()))
                    .collect(),
            ),
            KvDtype::Int8 => Store::Int8 {
                data: bytes[..n].iter().map(|&b| b as i8).collect(),
                scale: read_f32s(n, rows),
                zero: read_f32s(n + rows * 4, rows),
            },
        };
        Ok(KvArena { dim, rows, store })
    }
}

/// Borrowed encoded view of a run of rows — the encoding-tagged form
/// `head_slices` hands to the attention kernel. Consumers above the
/// kernel treat it as opaque; the fused kernels match on the variant.
#[derive(Debug, Clone, Copy)]
pub enum KvSlice<'a> {
    /// Raw f32 rows (`rows × dim` flat).
    F32(&'a [f32]),
    /// binary16 rows.
    F16 { data: &'a [u16], dim: usize },
    /// Per-row affine int8 rows plus metadata planes.
    Int8 { data: &'a [i8], scale: &'a [f32], zero: &'a [f32], dim: usize },
}

impl KvSlice<'_> {
    /// Storage dtype of the view.
    pub fn dtype(&self) -> KvDtype {
        match self {
            KvSlice::F32(_) => KvDtype::F32,
            KvSlice::F16 { .. } => KvDtype::F16,
            KvSlice::Int8 { .. } => KvDtype::Int8,
        }
    }

    /// Logical element count (`rows × dim`).
    pub fn elems(&self) -> usize {
        match self {
            KvSlice::F32(d) => d.len(),
            KvSlice::F16 { data, .. } => data.len(),
            KvSlice::Int8 { data, .. } => data.len(),
        }
    }

    /// Row count given the row width.
    pub fn rows(&self, dim: usize) -> usize {
        if dim == 0 {
            0
        } else {
            self.elems() / dim
        }
    }

    /// Decode row `row` into `out`.
    #[inline]
    pub fn decode_row_into(&self, row: usize, out: &mut [f32]) {
        match self {
            KvSlice::F32(d) => out.copy_from_slice(&d[row * out.len()..(row + 1) * out.len()]),
            KvSlice::F16 { data, dim } => {
                let at = row * dim;
                for (o, &h) in out.iter_mut().zip(&data[at..at + dim]) {
                    *o = f16_bits_to_f32(h);
                }
            }
            KvSlice::Int8 { data, scale, zero, dim } => {
                let (s, z) = (scale[row], zero[row]);
                let at = row * dim;
                for (o, &q) in out.iter_mut().zip(&data[at..at + dim]) {
                    *o = s * (q as f32 - z);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    #[test]
    fn f16_bits_roundtrip_exhaustively() {
        // Every one of the 65536 f16 bit patterns must survive
        // decode → encode unchanged (NaNs: NaN-ness preserved).
        for h in 0..=u16::MAX {
            let f = f16_bits_to_f32(h);
            let exp = (h >> 10) & 0x1F;
            let man = h & 0x03FF;
            if exp == 31 && man != 0 {
                assert!(f.is_nan(), "h={h:#06x}");
                assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "h={h:#06x} f={f}");
        }
    }

    #[test]
    fn f16_encode_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly between 1.0 and the next f16; RNE picks
        // the even mantissa (1.0).
        assert_eq!(f32_to_f16_bits(1.0 + 0.000_488_281_25), 0x3C00);
        // 1 + 3·2^-11 is between consecutive f16s; RNE picks the even
        // neighbour (mantissa 2).
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 0.000_488_281_25), 0x3C02);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // f16::MAX
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // rounds to inf
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
    }

    #[test]
    fn int8_rows_decode_within_half_step() {
        let mut rng = Pcg64::seed_from_u64(7);
        let dim = 16;
        let mut arena = KvArena::new(KvDtype::Int8, 8, dim);
        let mut out = vec![0.0f32; dim];
        for r in 0..8 {
            let src: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 2.0)).collect();
            arena.write_row(r, &src);
            arena.decode_row_into(r, &mut out);
            let span = src.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x))
                - src.iter().fold(f32::INFINITY, |a, &x| a.min(x));
            let step = span / 255.0;
            for (a, b) in out.iter().zip(&src) {
                assert!((a - b).abs() <= 0.51 * step.max(1e-6), "{a} vs {b} (step {step})");
            }
        }
    }

    #[test]
    fn constant_and_zero_rows_decode_exactly() {
        for dtype in KvDtype::ALL {
            let dim = 5;
            let mut arena = KvArena::new(dtype, 3, dim);
            let mut out = vec![9.0f32; dim];
            // Untouched rows decode to zero.
            arena.decode_row_into(0, &mut out);
            assert_eq!(out, vec![0.0; dim], "{dtype:?}");
            // Constant rows round-trip exactly under int8's affine map.
            arena.write_row(1, &[0.75; 5]);
            arena.decode_row_into(1, &mut out);
            assert_eq!(out, vec![0.75; dim], "{dtype:?}");
            // Written-then-zeroed rows decode to zero again.
            arena.write_row(2, &[1.5, -2.0, 0.25, 3.0, -0.5]);
            arena.zero_row(2);
            arena.decode_row_into(2, &mut out);
            assert_eq!(out, vec![0.0; dim], "{dtype:?}");
        }
    }

    #[test]
    fn byte_roundtrip_is_bit_identical() {
        let mut rng = Pcg64::seed_from_u64(11);
        for dtype in KvDtype::ALL {
            let (rows, dim) = (7, 6);
            let mut arena = KvArena::new(dtype, rows, dim);
            for r in 0..rows {
                let src: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect();
                arena.write_row(r, &src);
            }
            let mut bytes = Vec::new();
            arena.write_bytes(&mut bytes);
            assert_eq!(bytes.len(), arena.byte_len(), "{dtype:?}");
            assert_eq!(bytes.len(), rows * dtype.row_bytes(dim), "{dtype:?}");
            let back = KvArena::from_bytes(dtype, rows, dim, &bytes).unwrap();
            assert_eq!(back, arena, "{dtype:?}");
            assert!(KvArena::from_bytes(dtype, rows, dim, &bytes[..bytes.len() - 1]).is_err());
        }
    }

    #[test]
    fn copy_rows_preserves_encoded_bytes() {
        let mut rng = Pcg64::seed_from_u64(3);
        for dtype in KvDtype::ALL {
            let (rows, dim) = (6, 4);
            let mut src = KvArena::new(dtype, rows, dim);
            for r in 0..rows {
                let row: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect();
                src.write_row(r, &row);
            }
            let mut dst = KvArena::new(dtype, rows, dim);
            dst.copy_rows_from(&src, 1, 2, 3);
            let (mut a, mut b) = (vec![0.0f32; dim], vec![0.0f32; dim]);
            for i in 0..3 {
                src.decode_row_into(1 + i, &mut a);
                dst.decode_row_into(2 + i, &mut b);
                assert_eq!(a, b, "{dtype:?} row {i}");
            }
        }
    }

    #[test]
    fn slices_decode_like_the_arena() {
        let mut rng = Pcg64::seed_from_u64(5);
        for dtype in KvDtype::ALL {
            let (rows, dim) = (5, 3);
            let mut arena = KvArena::new(dtype, rows, dim);
            for r in 0..rows {
                let row: Vec<f32> = (0..dim).map(|_| rng.gaussian32(0.0, 1.0)).collect();
                arena.write_row(r, &row);
            }
            let view = arena.slice_rows(2, 3);
            assert_eq!(view.dtype(), dtype);
            assert_eq!(view.rows(dim), 3);
            let (mut a, mut b) = (vec![0.0f32; dim], vec![0.0f32; dim]);
            for i in 0..3 {
                view.decode_row_into(i, &mut a);
                arena.decode_row_into(2 + i, &mut b);
                assert_eq!(a, b, "{dtype:?} row {i}");
            }
        }
    }

    #[test]
    fn dtype_parse_and_index_roundtrip() {
        for dtype in KvDtype::ALL {
            assert_eq!(KvDtype::parse(dtype.name()).unwrap(), dtype);
            assert_eq!(KvDtype::from_index(dtype.index()).unwrap(), dtype);
        }
        assert!(KvDtype::parse("f64").is_err());
        assert!(KvDtype::from_index(3).is_err());
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.decode_tolerance(), 0.0);
        assert!(KvDtype::F16.decode_tolerance() < KvDtype::Int8.decode_tolerance());
    }
}
