//! # SubGen — sublinear-time/memory token generation
//!
//! A production-shaped reproduction of *SubGen: Token Generation in
//! Sublinear Time and Memory* (Zandieh, Han, Mirrokni, Karbasi; 2024):
//! KV-cache compression for autoregressive LLM decoding via online
//! clustering of keys and ℓ2 sampling of values, with a provable
//! spectral-error guarantee.
//!
//! Layer map (see DESIGN.md):
//! * **algorithm** — [`subgen`], [`clustering`], [`sampling`],
//!   [`attention`]: the paper's Algorithm 1 and its substrates;
//! * **serving** — [`kvcache`], [`coordinator`], [`server`],
//!   [`runtime`], [`model`]: a vLLM-style rust serving stack with cache
//!   policies as a first-class feature, running AOT-compiled JAX/Pallas
//!   artifacts via PJRT;
//! * **experiments** — [`workload`], [`train`], [`tsne`], [`bench`],
//!   [`metrics`], [`trace`]: everything needed to regenerate the
//!   paper's Table 1
//!   and Figure 1 plus the Theorem-1 scaling studies, including pure-
//!   rust training of the host transformer on the retrieval task;
//! * **substrates** — [`rng`], [`tensor`], [`linalg`], [`cli`],
//!   [`config`], [`io`], [`proptest_lite`], [`xla`]: the utility layer
//!   this sandbox would normally pull from crates.io, built from
//!   scratch (including the host-side PJRT stand-in).

pub mod attention;
pub mod bench;
pub mod cli;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod io;
pub mod kvcache;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod proptest_lite;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod server;
pub mod subgen;
pub mod tensor;
pub mod trace;
pub mod train;
pub mod tsne;
pub mod workload;
pub mod xla;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
