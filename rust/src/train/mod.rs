//! Pure-rust training for the host transformer — the closing of the
//! loop between the serving stack and the paper's empirical claim.
//!
//! The `HostExecutor` gave the engine a real autoregressive model, but
//! with random weights every policy scored chance-level on retrieval:
//! the repo could measure *fidelity to the exact cache*, never task
//! accuracy. This module fits those same weights on the line-retrieval
//! workload so the pure-rust stack reproduces the shape of the paper's
//! Table 1 (per-policy accuracy at matched memory budgets) end to end,
//! with no PJRT artifacts:
//!
//! * [`ParamSet`] — all weights in one flat arena with named segments,
//!   exchanged with executors and disk via [`crate::io::Checkpoint`];
//! * [`TrainModel`] — forward pass mirroring `HostExecutor::prefill`
//!   op for op, plus a hand-derived backward (finite-difference
//!   verified over every parameter);
//! * [`Optimizer`] — SGD-with-momentum and Adam as flat elementwise
//!   sweeps; [`clip_grad_norm`] for stability;
//! * [`Trainer`] — mini-batches over [`crate::workload::RetrievalSampler`]
//!   documents, cross-entropy masked to the answer tokens, greedy
//!   held-out accuracy with early stopping;
//! * [`evaluate_policies`] — the Table-1 harness: the trained model
//!   decodes held-out documents through the serving engine under every
//!   cache policy at matched budgets ([`accuracy_json`] emits the
//!   trend-tracking JSON).
//!
//! Driven by `subgen train` / `subgen eval` and
//! `examples/eval_retrieval.rs`; the end-to-end accuracy bar lives in
//! `rust/tests/integration_train.rs`.

mod eval;
mod model;
mod optim;
mod params;
mod trainer;

pub use eval::{accuracy_json, accuracy_json_encoded, evaluate_policies, EvalConfig, PolicyAccuracy};
pub use model::{Tape, TrainModel};
pub use optim::{clip_grad_norm, OptimKind, Optimizer};
pub use params::ParamSet;
pub use trainer::{greedy_accuracy, TrainConfig, TrainReport, Trainer};
