//! Flat parameter arena for the trainable host transformer.
//!
//! All weights live in **one** contiguous `Vec<f32>` with named
//! segments, mirroring the [`crate::model::HostExecutor`] layout
//! (embeddings, per-layer attention + MLP weights, final norm gain).
//! The flat layout is what makes the optimizer trivial — SGD/Adam are
//! elementwise sweeps over three same-length buffers — and checkpoint
//! export is a walk over the named segments, so the trainer, disk, and
//! the serving executor all exchange the same
//! [`crate::io::Checkpoint`] tensors.

use crate::io::Checkpoint;
use crate::model::ModelSpec;
use crate::rng::{fill_gaussian, Pcg64};
use anyhow::Result;

/// Embedding init std (tied output head: small init keeps the initial
/// logits near-uniform under RMSNorm, which trains stably).
const EMBED_STD: f32 = 0.1;

/// One named segment of the arena.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Seg {
    /// Offset into the arena.
    pub at: usize,
    /// Element count.
    pub len: usize,
}

impl Seg {
    /// Borrow this segment of `data`.
    #[inline]
    pub fn of<'a>(&self, data: &'a [f32]) -> &'a [f32] {
        &data[self.at..self.at + self.len]
    }

    /// Mutably borrow this segment of `data`.
    #[inline]
    pub fn of_mut<'a>(&self, data: &'a mut [f32]) -> &'a mut [f32] {
        &mut data[self.at..self.at + self.len]
    }
}

/// Per-layer segments, in arena order.
#[derive(Clone, Copy, Debug)]
pub(crate) struct LayerSegs {
    pub g_attn: Seg,
    pub wq: Seg,
    pub wk: Seg,
    pub wv: Seg,
    pub wo: Seg,
    pub g_mlp: Seg,
    pub w1: Seg,
    pub w2: Seg,
}

/// All parameters of one model, flat.
pub struct ParamSet {
    spec: ModelSpec,
    data: Vec<f32>,
    pub(crate) embed: Seg,
    pub(crate) g_final: Seg,
    pub(crate) layers: Vec<LayerSegs>,
}

impl ParamSet {
    /// Zero-initialized arena with the layout for `spec`.
    pub fn zeros(spec: ModelSpec) -> Result<ParamSet> {
        anyhow::ensure!(spec.vocab > 0 && spec.d_model > 0, "degenerate spec");
        anyhow::ensure!(spec.n_layers > 0 && spec.n_heads > 0, "degenerate spec");
        anyhow::ensure!(spec.d_head % 2 == 0, "RoPE needs an even d_head");
        anyhow::ensure!(!spec.cache_variants.is_empty(), "spec has no cache variants");
        let (dm, hd, d_ff) = (spec.d_model, spec.n_heads * spec.d_head, spec.d_ff());
        let mut at = 0usize;
        let mut seg = |len: usize| {
            let s = Seg { at, len };
            at += len;
            s
        };
        let embed = seg(spec.vocab * dm);
        let layers: Vec<LayerSegs> = (0..spec.n_layers)
            .map(|_| LayerSegs {
                g_attn: seg(dm),
                wq: seg(hd * dm),
                wk: seg(hd * dm),
                wv: seg(hd * dm),
                wo: seg(dm * hd),
                g_mlp: seg(dm),
                w1: seg(d_ff * dm),
                w2: seg(dm * d_ff),
            })
            .collect();
        let g_final = seg(dm);
        Ok(ParamSet { spec, data: vec![0.0; at], embed, g_final, layers })
    }

    /// Training init: gaussian weights from `seed` (scaled-down output
    /// projections for residual stability), unit norm gains.
    pub fn init(spec: ModelSpec, seed: u64) -> Result<ParamSet> {
        let mut p = Self::zeros(spec)?;
        let spec = p.spec.clone();
        let (dm, hd, d_ff) = (spec.d_model, spec.n_heads * spec.d_head, spec.d_ff());
        let proj_std = 1.0 / (dm as f32).sqrt();
        let resid = 1.0 / (2.0 * spec.n_layers as f32).sqrt();
        let mut rng = Pcg64::seed_from_u64(seed ^ 0x7121_1EA4);
        fill_gaussian(&mut rng, p.embed.of_mut(&mut p.data), EMBED_STD);
        for l in 0..spec.n_layers {
            let s = p.layers[l];
            p.data[s.g_attn.at..s.g_attn.at + s.g_attn.len].fill(1.0);
            p.data[s.g_mlp.at..s.g_mlp.at + s.g_mlp.len].fill(1.0);
            fill_gaussian(&mut rng, s.wq.of_mut(&mut p.data), proj_std);
            fill_gaussian(&mut rng, s.wk.of_mut(&mut p.data), proj_std);
            fill_gaussian(&mut rng, s.wv.of_mut(&mut p.data), proj_std);
            fill_gaussian(&mut rng, s.wo.of_mut(&mut p.data), resid / (hd as f32).sqrt());
            fill_gaussian(&mut rng, s.w1.of_mut(&mut p.data), proj_std);
            fill_gaussian(&mut rng, s.w2.of_mut(&mut p.data), resid / (d_ff as f32).sqrt());
        }
        p.data[p.g_final.at..p.g_final.at + p.g_final.len].fill(1.0);
        Ok(p)
    }

    /// Model shapes.
    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Record the trained accuracy carried into exported checkpoints.
    pub fn set_train_accuracy(&mut self, acc: f64) {
        self.spec.train_accuracy = acc;
    }

    /// Total parameter count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// The flat arena.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// The flat arena, mutable (optimizer updates).
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Named segments in arena order: `(name, dims, segment)` — the
    /// checkpoint schema shared with `HostExecutor::to_checkpoint`.
    pub(crate) fn entries(&self) -> Vec<(String, Vec<usize>, Seg)> {
        let (v, dm) = (self.spec.vocab, self.spec.d_model);
        let (hd, d_ff) = (self.spec.n_heads * self.spec.d_head, self.spec.d_ff());
        let mut out = vec![("embed".to_string(), vec![v, dm], self.embed)];
        for (l, s) in self.layers.iter().enumerate() {
            let name = |f: &str| format!("layers.{l}.{f}");
            out.push((name("g_attn"), vec![dm], s.g_attn));
            out.push((name("wq"), vec![hd, dm], s.wq));
            out.push((name("wk"), vec![hd, dm], s.wk));
            out.push((name("wv"), vec![hd, dm], s.wv));
            out.push((name("wo"), vec![dm, hd], s.wo));
            out.push((name("g_mlp"), vec![dm], s.g_mlp));
            out.push((name("w1"), vec![d_ff, dm], s.w1));
            out.push((name("w2"), vec![dm, d_ff], s.w2));
        }
        out.push(("g_final".to_string(), vec![dm], self.g_final));
        out
    }

    /// Export as a checkpoint (weights + spec metadata).
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        self.spec.write_checkpoint_meta(&mut ck);
        for (name, dims, seg) in self.entries() {
            ck.insert(&name, dims, seg.of(&self.data).to_vec());
        }
        ck
    }

    /// Rebuild from a checkpoint (spec metadata + every named tensor,
    /// shape-checked) — accepts both trainer- and executor-written
    /// checkpoints; they share one schema.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<ParamSet> {
        let spec = ModelSpec::read_checkpoint_meta(ck)?;
        let mut p = Self::zeros(spec)?;
        for (name, dims, seg) in p.entries() {
            let t = ck.require(&name)?;
            anyhow::ensure!(t.dims == dims, "{name}: shaped {:?}, want {:?}", t.dims, dims);
            p.data[seg.at..seg.at + seg.len].copy_from_slice(&t.data);
        }
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::HostExecutor;

    fn spec() -> ModelSpec {
        ModelSpec {
            vocab: 16,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_head: 8,
            prefill_t: 64,
            cache_variants: vec![64, 32],
            decode_batch: 0,
            train_accuracy: -1.0,
        }
    }

    #[test]
    fn layout_covers_arena_exactly() {
        let p = ParamSet::zeros(spec()).unwrap();
        let mut seen = vec![false; p.len()];
        for (_, dims, seg) in p.entries() {
            assert_eq!(dims.iter().product::<usize>(), seg.len);
            for s in &mut seen[seg.at..seg.at + seg.len] {
                assert!(!*s, "overlapping segments");
                *s = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "arena has unnamed gaps");
    }

    #[test]
    fn checkpoint_roundtrip_preserves_bits() {
        let mut p = ParamSet::init(spec(), 5).unwrap();
        p.set_train_accuracy(0.875);
        let back = ParamSet::from_checkpoint(&p.to_checkpoint()).unwrap();
        assert_eq!(back.data(), p.data());
        assert!((back.spec().train_accuracy - 0.875).abs() < 1e-6);
    }

    #[test]
    fn checkpoint_schema_matches_host_executor() {
        // A host-executor checkpoint loads as a ParamSet and vice versa
        // (one schema both directions).
        let m = HostExecutor::small(9);
        let p = ParamSet::from_checkpoint(&m.to_checkpoint()).unwrap();
        let again = HostExecutor::from_checkpoint(&p.to_checkpoint()).unwrap();
        let a = m.prefill(&[1, 2, 3]).unwrap();
        let b = again.prefill(&[1, 2, 3]).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn init_is_deterministic_by_seed() {
        let a = ParamSet::init(spec(), 3).unwrap();
        let b = ParamSet::init(spec(), 3).unwrap();
        let c = ParamSet::init(spec(), 4).unwrap();
        assert_eq!(a.data(), b.data());
        assert_ne!(a.data(), c.data());
        assert!(a.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rejects_odd_d_head() {
        let mut s = spec();
        s.d_head = 7;
        assert!(ParamSet::zeros(s).is_err());
    }
}
