//! The trainable form of the host transformer: a full-sequence forward
//! pass that records every activation on a [`Tape`], and a manual
//! backward pass producing exact gradients for all parameters.
//!
//! The forward math mirrors [`crate::model::HostExecutor::prefill`]
//! operation for operation — embeddings, pre-norm RMSNorm, q/k/v
//! projections, RoPE (shared frequency table), `1/√d_head` query
//! scaling, causal softmax attention, SiLU MLP, tied output logits — so
//! weights trained here and exported through
//! [`crate::io::Checkpoint`] *are* the serving model
//! (`tests` pin trainer-forward ≡ executor-prefill). The backward pass
//! is hand-derived per block (RMSNorm, RoPE rotation transpose,
//! softmax-attention, SiLU, tied embeddings) and verified against
//! central finite differences over every parameter.

use super::params::ParamSet;
use crate::io::Checkpoint;
use crate::model::{rope_freqs, rope_inplace, silu_inplace, ModelSpec, NORM_EPS};
use crate::tensor::{argmax, axpy, dot, matvec_batch_into, matvec_into};
use anyhow::Result;

/// Activation record of one forward pass, plus reusable backward
/// scratch. Grown to the largest sequence seen; reused across calls.
#[derive(Default)]
pub struct Tape {
    t: usize,
    tokens: Vec<i32>,
    /// Residual stream entering each layer plus the final one,
    /// `n_layers + 1` buffers of `[T, dm]`.
    xs: Vec<Vec<f32>>,
    /// Pre-attention RMSNorm outputs, per layer `[T, dm]`.
    a_norm: Vec<Vec<f32>>,
    /// Pre-attention RMSNorm `1/rms` per row, per layer `[T]`.
    inv_attn: Vec<Vec<f32>>,
    /// Post-RoPE (and, for q, post-scale) projections, per layer `[T, hd]`.
    q: Vec<Vec<f32>>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// Causal softmax weights, per layer `[H, T, T]` (rows past the
    /// diagonal unused).
    probs: Vec<Vec<f32>>,
    /// Concatenated head outputs, per layer `[T, hd]`.
    att: Vec<Vec<f32>>,
    /// Residual after the attention block, per layer `[T, dm]`.
    x_mid: Vec<Vec<f32>>,
    /// Pre-MLP RMSNorm outputs / inverse rms, per layer.
    b_norm: Vec<Vec<f32>>,
    inv_mlp: Vec<Vec<f32>>,
    /// MLP hidden pre-/post-SiLU, per layer `[T, d_ff]`.
    ff_pre: Vec<Vec<f32>>,
    ff_act: Vec<Vec<f32>>,
    /// Final RMSNorm outputs `[T, dm]` and inverse rms `[T]`.
    hfin: Vec<f32>,
    inv_fin: Vec<f32>,
    /// Output logits `[T, vocab]`.
    logits: Vec<f32>,
    // ── backward scratch (sized with the forward buffers) ──
    dxs: Vec<f32>,
    dmid: Vec<f32>,
    datt: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    vec_dm: Vec<f32>,
    vec_dm2: Vec<f32>,
    vec_ff: Vec<f32>,
    vec_ff2: Vec<f32>,
    vec_vocab: Vec<f32>,
    scores: Vec<f32>,
}

impl Tape {
    /// Fresh empty tape.
    pub fn new() -> Tape {
        Tape::default()
    }

    /// Sequence length of the recorded pass.
    pub fn len(&self) -> usize {
        self.t
    }

    /// True before any forward pass.
    pub fn is_empty(&self) -> bool {
        self.t == 0
    }

    /// All logits of the recorded pass, `[T, vocab]` row-major.
    pub fn logits(&self) -> &[f32] {
        &self.logits
    }

    /// Logits at one position.
    pub fn logits_at(&self, pos: usize, vocab: usize) -> &[f32] {
        &self.logits[pos * vocab..(pos + 1) * vocab]
    }

    fn ensure(&mut self, spec: &ModelSpec, t: usize) {
        let (l, dm, v) = (spec.n_layers, spec.d_model, spec.vocab);
        let (h, hd, d_ff) = (spec.n_heads, spec.n_heads * spec.d_head, spec.d_ff());
        let grow = |bufs: &mut Vec<Vec<f32>>, n: usize, len: usize| {
            bufs.resize_with(n, Vec::new);
            for b in bufs.iter_mut() {
                b.resize(len, 0.0);
            }
        };
        grow(&mut self.xs, l + 1, t * dm);
        grow(&mut self.a_norm, l, t * dm);
        grow(&mut self.inv_attn, l, t);
        grow(&mut self.q, l, t * hd);
        grow(&mut self.k, l, t * hd);
        grow(&mut self.v, l, t * hd);
        grow(&mut self.probs, l, h * t * t);
        grow(&mut self.att, l, t * hd);
        grow(&mut self.x_mid, l, t * dm);
        grow(&mut self.b_norm, l, t * dm);
        grow(&mut self.inv_mlp, l, t);
        grow(&mut self.ff_pre, l, t * d_ff);
        grow(&mut self.ff_act, l, t * d_ff);
        self.hfin.resize(t * dm, 0.0);
        self.inv_fin.resize(t, 0.0);
        self.logits.resize(t * v, 0.0);
        self.dxs.resize(t * dm, 0.0);
        self.dmid.resize(t * dm, 0.0);
        self.datt.resize(t * hd, 0.0);
        self.dq.resize(t * hd, 0.0);
        self.dk.resize(t * hd, 0.0);
        self.dv.resize(t * hd, 0.0);
        self.vec_dm.resize(dm, 0.0);
        self.vec_dm2.resize(dm, 0.0);
        self.vec_ff.resize(d_ff, 0.0);
        self.vec_ff2.resize(d_ff, 0.0);
        self.vec_vocab.resize(v, 0.0);
        self.scores.resize(t, 0.0);
        self.t = t;
    }
}

/// `out = x · g / rms`, returning `1/rms` for the backward pass.
fn rmsnorm_fwd(x: &[f32], g: &[f32], out: &mut [f32]) -> f32 {
    let inv = 1.0 / (dot(x, x) / x.len() as f32 + NORM_EPS).sqrt();
    for ((o, &xi), &gi) in out.iter_mut().zip(x).zip(g) {
        *o = xi * inv * gi;
    }
    inv
}

/// RMSNorm backward: given `dy` for `y = x·g·inv`, overwrite `dx` and
/// accumulate `dg`. `c = Σ dy·g·x` folds the `1/rms` dependence on `x`.
fn rmsnorm_bwd(x: &[f32], g: &[f32], inv: f32, dy: &[f32], dx: &mut [f32], dg: &mut [f32]) {
    let n = x.len() as f32;
    let mut c = 0.0f32;
    for ((&dyi, &gi), &xi) in dy.iter().zip(g).zip(x) {
        c += dyi * gi * xi;
    }
    let k = c * inv * inv * inv / n;
    for (j, dxj) in dx.iter_mut().enumerate() {
        *dxj = inv * dy[j] * g[j] - x[j] * k;
        dg[j] += dy[j] * x[j] * inv;
    }
}

/// Transpose (inverse) of the RoPE rotation at `pos`, in place — the
/// backward of [`rope_inplace`].
fn rope_bwd(x: &mut [f32], n_heads: usize, freqs: &[f32], pos: usize) {
    let dh = 2 * freqs.len();
    for h in 0..n_heads {
        let head = &mut x[h * dh..(h + 1) * dh];
        for (i, &f) in freqs.iter().enumerate() {
            let (sin, cos) = (pos as f32 * f).sin_cos();
            let a = head[2 * i];
            let b = head[2 * i + 1];
            head[2 * i] = a * cos + b * sin;
            head[2 * i + 1] = -a * sin + b * cos;
        }
    }
}

/// `dx = Wᵀ dy` for row-major `W [rows(dy), cols]` (overwrites `dx`).
fn matvec_t_into(w: &[f32], cols: usize, dy: &[f32], dx: &mut [f32]) {
    dx.fill(0.0);
    matvec_t_accum(w, cols, dy, dx);
}

/// `dx += Wᵀ dy`.
fn matvec_t_accum(w: &[f32], cols: usize, dy: &[f32], dx: &mut [f32]) {
    for (i, &g) in dy.iter().enumerate() {
        if g != 0.0 {
            axpy(g, &w[i * cols..(i + 1) * cols], dx);
        }
    }
}

/// `dW += dy ⊗ x` for row-major `dW [rows(dy), cols(x)]`.
fn accum_outer(dw: &mut [f32], dy: &[f32], x: &[f32]) {
    let cols = x.len();
    for (i, &g) in dy.iter().enumerate() {
        if g != 0.0 {
            axpy(g, x, &mut dw[i * cols..(i + 1) * cols]);
        }
    }
}

/// The trainable host transformer.
pub struct TrainModel {
    params: ParamSet,
    rope: Vec<f32>,
}

impl TrainModel {
    /// Fresh training init (see [`ParamSet::init`]).
    pub fn init(spec: ModelSpec, seed: u64) -> Result<TrainModel> {
        Ok(Self::from_params(ParamSet::init(spec, seed)?))
    }

    /// Wrap an existing parameter set.
    pub fn from_params(params: ParamSet) -> TrainModel {
        let rope = rope_freqs(params.spec().d_head);
        TrainModel { params, rope }
    }

    /// Rebuild from a checkpoint (trainer- or executor-written).
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TrainModel> {
        Ok(Self::from_params(ParamSet::from_checkpoint(ck)?))
    }

    /// Export weights + spec metadata.
    pub fn to_checkpoint(&self) -> Checkpoint {
        self.params.to_checkpoint()
    }

    /// Model shapes.
    pub fn spec(&self) -> &ModelSpec {
        self.params.spec()
    }

    /// The parameter arena.
    pub fn params(&self) -> &ParamSet {
        &self.params
    }

    /// The parameter arena, mutable (optimizer updates).
    pub fn params_mut(&mut self) -> &mut ParamSet {
        &mut self.params
    }

    /// Full-sequence causal forward pass, recording activations on
    /// `tape` (logits at every position land in [`Tape::logits`]).
    pub fn forward(&self, tokens: &[i32], tape: &mut Tape) -> Result<()> {
        let spec = self.params.spec().clone();
        let (t, dm, vocab) = (tokens.len(), spec.d_model, spec.vocab);
        let (h, dh, d_ff) = (spec.n_heads, spec.d_head, spec.d_ff());
        let hd = h * dh;
        anyhow::ensure!(t >= 1, "empty sequence");
        let q_scale = 1.0 / (dh as f32).sqrt();
        tape.ensure(&spec, t);
        tape.tokens.clear();
        tape.tokens.extend_from_slice(tokens);
        let p = self.params.data();
        let embed = self.params.embed.of(p);

        for (pos, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!((0..vocab as i32).contains(&tok), "token {tok} outside vocab {vocab}");
            let row = tok as usize * dm;
            tape.xs[0][pos * dm..(pos + 1) * dm].copy_from_slice(&embed[row..row + dm]);
        }

        for (l, seg) in self.params.layers.iter().enumerate() {
            let (g_attn, g_mlp) = (seg.g_attn.of(p), seg.g_mlp.of(p));
            let (wq, wk, wv, wo) = (seg.wq.of(p), seg.wk.of(p), seg.wv.of(p), seg.wo.of(p));
            let (w1, w2) = (seg.w1.of(p), seg.w2.of(p));
            // Split disjoint tape buffers for simultaneous borrows.
            let (xs_in, xs_rest) = tape.xs.split_at_mut(l + 1);
            let x = &xs_in[l];
            let x_next = &mut xs_rest[0];
            // Projections run batched over the whole sequence (each
            // weight row is loaded once per layer, not once per
            // position); `matvec_batch_into`'s `out[pos * rows + r]`
            // layout is the tape's per-position layout, and its inner
            // reduction is the same `dot`, so results are bit-identical
            // to the per-position `matvec_into` loop (pinned in tests).
            for pos in 0..t {
                let a = &mut tape.a_norm[l][pos * dm..(pos + 1) * dm];
                tape.inv_attn[l][pos] = rmsnorm_fwd(&x[pos * dm..(pos + 1) * dm], g_attn, a);
            }
            matvec_batch_into(wq, dm, &tape.a_norm[l][..t * dm], t, &mut tape.q[l][..t * hd]);
            matvec_batch_into(wk, dm, &tape.a_norm[l][..t * dm], t, &mut tape.k[l][..t * hd]);
            matvec_batch_into(wv, dm, &tape.a_norm[l][..t * dm], t, &mut tape.v[l][..t * hd]);
            for pos in 0..t {
                let qp = &mut tape.q[l][pos * hd..(pos + 1) * hd];
                rope_inplace(qp, h, &self.rope, pos);
                for qi in qp.iter_mut() {
                    *qi *= q_scale;
                }
                rope_inplace(&mut tape.k[l][pos * hd..(pos + 1) * hd], h, &self.rope, pos);
            }
            // Causal softmax attention per (head, position).
            for hi in 0..h {
                for pos in 0..t {
                    let qrow = &tape.q[l][pos * hd + hi * dh..pos * hd + (hi + 1) * dh];
                    let mut m = f32::NEG_INFINITY;
                    for tt in 0..=pos {
                        let krow = &tape.k[l][tt * hd + hi * dh..tt * hd + (hi + 1) * dh];
                        tape.scores[tt] = dot(qrow, krow);
                        m = m.max(tape.scores[tt]);
                    }
                    let mut z = 0.0f64;
                    for tt in 0..=pos {
                        tape.scores[tt] = (tape.scores[tt] - m).exp();
                        z += tape.scores[tt] as f64;
                    }
                    let invz = (1.0 / z) as f32;
                    let prow = &mut tape.probs[l][(hi * t + pos) * t..(hi * t + pos) * t + t];
                    let mut acc = [0.0f64; 64];
                    debug_assert!(dh <= 64, "head width above scratch bound");
                    for tt in 0..=pos {
                        let w = tape.scores[tt] * invz;
                        prow[tt] = w;
                        let vrow = &tape.v[l][tt * hd + hi * dh..tt * hd + (hi + 1) * dh];
                        for (aj, &vj) in acc[..dh].iter_mut().zip(vrow) {
                            *aj += w as f64 * vj as f64;
                        }
                    }
                    let orow = &mut tape.att[l][pos * hd + hi * dh..pos * hd + (hi + 1) * dh];
                    for (oj, &aj) in orow.iter_mut().zip(&acc[..dh]) {
                        *oj = aj as f32;
                    }
                }
            }
            // Output projection + residual, then the MLP block — every
            // matvec batched over positions; the residual adds keep the
            // original operand order so sums stay bit-identical.
            matvec_batch_into(wo, hd, &tape.att[l][..t * hd], t, &mut tape.x_mid[l][..t * dm]);
            for pos in 0..t {
                let xm = &mut tape.x_mid[l][pos * dm..(pos + 1) * dm];
                for (j, xj) in xm.iter_mut().enumerate() {
                    *xj = x[pos * dm + j] + *xj;
                }
                let b = &mut tape.b_norm[l][pos * dm..(pos + 1) * dm];
                tape.inv_mlp[l][pos] = rmsnorm_fwd(xm, g_mlp, b);
            }
            let pre = &mut tape.ff_pre[l][..t * d_ff];
            matvec_batch_into(w1, dm, &tape.b_norm[l][..t * dm], t, pre);
            let act = &mut tape.ff_act[l][..t * d_ff];
            act.copy_from_slice(&tape.ff_pre[l][..t * d_ff]);
            silu_inplace(act);
            matvec_batch_into(w2, d_ff, &tape.ff_act[l][..t * d_ff], t, &mut x_next[..t * dm]);
            for pos in 0..t {
                let xn = &mut x_next[pos * dm..(pos + 1) * dm];
                for (j, xj) in xn.iter_mut().enumerate() {
                    *xj = tape.x_mid[l][pos * dm + j] + *xj;
                }
            }
        }

        // Final norm + tied logits (one batched sweep over the
        // vocab-sized embedding — the trainer's largest matvec).
        let g_final = self.params.g_final.of(p);
        let x_last = &tape.xs[spec.n_layers];
        for pos in 0..t {
            let hf = &mut tape.hfin[pos * dm..(pos + 1) * dm];
            tape.inv_fin[pos] = rmsnorm_fwd(&x_last[pos * dm..(pos + 1) * dm], g_final, hf);
        }
        matvec_batch_into(embed, dm, &tape.hfin[..t * dm], t, &mut tape.logits[..t * vocab]);
        Ok(())
    }

    /// Backward pass for summed cross-entropy at `targets`
    /// (`(position, target token)` pairs): accumulates parameter
    /// gradients into `grads` (same layout as the arena, **not**
    /// zeroed here) and returns the summed loss. Callers average by
    /// scaling `grads` afterwards.
    pub fn backward(
        &self,
        tape: &mut Tape,
        targets: &[(usize, i32)],
        grads: &mut [f32],
    ) -> Result<f64> {
        let spec = self.params.spec().clone();
        let (t, dm, vocab) = (tape.t, spec.d_model, spec.vocab);
        let (h, dh, d_ff) = (spec.n_heads, spec.d_head, spec.d_ff());
        let hd = h * dh;
        anyhow::ensure!(t >= 1, "backward before forward");
        anyhow::ensure!(grads.len() == self.params.len(), "gradient buffer length mismatch");
        let q_scale = 1.0 / (dh as f32).sqrt();
        let p = self.params.data();
        let embed = self.params.embed.of(p);
        let g_final = self.params.g_final.of(p);

        // ── Head: CE → logits → tied embed → final norm ──
        tape.dxs.fill(0.0);
        let mut loss = 0.0f64;
        for &(pos, target) in targets {
            anyhow::ensure!(pos < t, "target position {pos} ≥ sequence length {t}");
            anyhow::ensure!((0..vocab as i32).contains(&target), "target {target} outside vocab");
            let logits = &tape.logits[pos * vocab..(pos + 1) * vocab];
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f64;
            for &x in logits {
                z += ((x - m) as f64).exp();
            }
            loss += z.ln() - (logits[target as usize] - m) as f64;
            let dlog = &mut tape.vec_vocab;
            for (i, dl) in dlog.iter_mut().enumerate() {
                *dl = (((logits[i] - m) as f64).exp() / z) as f32;
            }
            dlog[target as usize] -= 1.0;
            let hf = &tape.hfin[pos * dm..(pos + 1) * dm];
            // d hfin = Eᵀ dlogits; dE += dlogits ⊗ hfin.
            matvec_t_into(embed, dm, dlog, &mut tape.vec_dm);
            accum_outer(self.params.embed.of_mut(grads), dlog, hf);
            let x_last = &tape.xs[spec.n_layers][pos * dm..(pos + 1) * dm];
            rmsnorm_bwd(
                x_last,
                g_final,
                tape.inv_fin[pos],
                &tape.vec_dm,
                &mut tape.vec_dm2,
                self.params.g_final.of_mut(grads),
            );
            for (j, &d) in tape.vec_dm2.iter().enumerate() {
                tape.dxs[pos * dm + j] += d;
            }
        }

        // ── Layers in reverse ──
        for l in (0..spec.n_layers).rev() {
            let seg = self.params.layers[l];
            let (g_attn, g_mlp) = (seg.g_attn.of(p), seg.g_mlp.of(p));
            let (wq, wk, wv, wo) = (seg.wq.of(p), seg.wk.of(p), seg.wv.of(p), seg.wo.of(p));
            let (w1, w2) = (seg.w1.of(p), seg.w2.of(p));
            // MLP block backward (dxs currently holds d xs[l+1]).
            for pos in 0..t {
                let dx3 = &tape.dxs[pos * dm..(pos + 1) * dm];
                let act = &tape.ff_act[l][pos * d_ff..(pos + 1) * d_ff];
                matvec_t_into(w2, d_ff, dx3, &mut tape.vec_ff);
                accum_outer(seg.w2.of_mut(grads), dx3, act);
                let pre = &tape.ff_pre[l][pos * d_ff..(pos + 1) * d_ff];
                for (j, dfp) in tape.vec_ff2.iter_mut().enumerate() {
                    let s = 1.0 / (1.0 + (-pre[j]).exp());
                    *dfp = tape.vec_ff[j] * s * (1.0 + pre[j] * (1.0 - s));
                }
                let b = &tape.b_norm[l][pos * dm..(pos + 1) * dm];
                accum_outer(seg.w1.of_mut(grads), &tape.vec_ff2, b);
                matvec_t_into(w1, dm, &tape.vec_ff2, &mut tape.vec_dm);
                let xm = &tape.x_mid[l][pos * dm..(pos + 1) * dm];
                rmsnorm_bwd(
                    xm,
                    g_mlp,
                    tape.inv_mlp[l][pos],
                    &tape.vec_dm,
                    &mut tape.vec_dm2,
                    seg.g_mlp.of_mut(grads),
                );
                let dmid = &mut tape.dmid[pos * dm..(pos + 1) * dm];
                for (j, dj) in dmid.iter_mut().enumerate() {
                    *dj = dx3[j] + tape.vec_dm2[j];
                }
            }
            // Attention output projection backward.
            for pos in 0..t {
                let dmid = &tape.dmid[pos * dm..(pos + 1) * dm];
                matvec_t_into(wo, hd, dmid, &mut tape.datt[pos * hd..(pos + 1) * hd]);
                accum_outer(seg.wo.of_mut(grads), dmid, &tape.att[l][pos * hd..(pos + 1) * hd]);
            }
            // Softmax attention backward per (head, position).
            tape.dq.fill(0.0);
            tape.dk.fill(0.0);
            tape.dv.fill(0.0);
            for hi in 0..h {
                let at = hi * dh;
                for pos in 0..t {
                    let dout = {
                        let s = &tape.datt[pos * hd + at..pos * hd + at + dh];
                        let mut buf = [0.0f32; 64];
                        buf[..dh].copy_from_slice(s);
                        buf
                    };
                    let dout = &dout[..dh];
                    let prow = &tape.probs[l][(hi * t + pos) * t..(hi * t + pos) * t + t];
                    let mut sum = 0.0f32;
                    for tt in 0..=pos {
                        let vrow = &tape.v[l][tt * hd + at..tt * hd + at + dh];
                        tape.scores[tt] = dot(dout, vrow);
                        sum += prow[tt] * tape.scores[tt];
                    }
                    let qrow = {
                        let s = &tape.q[l][pos * hd + at..pos * hd + at + dh];
                        let mut buf = [0.0f32; 64];
                        buf[..dh].copy_from_slice(s);
                        buf
                    };
                    for tt in 0..=pos {
                        let ds = prow[tt] * (tape.scores[tt] - sum);
                        let krow = &tape.k[l][tt * hd + at..tt * hd + at + dh];
                        axpy(ds, krow, &mut tape.dq[pos * hd + at..pos * hd + at + dh]);
                        axpy(ds, &qrow[..dh], &mut tape.dk[tt * hd + at..tt * hd + at + dh]);
                        axpy(prow[tt], dout, &mut tape.dv[tt * hd + at..tt * hd + at + dh]);
                    }
                }
            }
            // Undo query scale + RoPE, then project back to the norm.
            for pos in 0..t {
                let dqp = &mut tape.dq[pos * hd..(pos + 1) * hd];
                for d in dqp.iter_mut() {
                    *d *= q_scale;
                }
                rope_bwd(dqp, h, &self.rope, pos);
                rope_bwd(&mut tape.dk[pos * hd..(pos + 1) * hd], h, &self.rope, pos);
            }
            let x = &tape.xs[l];
            for pos in 0..t {
                let a = &tape.a_norm[l][pos * dm..(pos + 1) * dm];
                let dqp = &tape.dq[pos * hd..(pos + 1) * hd];
                let dkp = &tape.dk[pos * hd..(pos + 1) * hd];
                let dvp = &tape.dv[pos * hd..(pos + 1) * hd];
                accum_outer(seg.wq.of_mut(grads), dqp, a);
                accum_outer(seg.wk.of_mut(grads), dkp, a);
                accum_outer(seg.wv.of_mut(grads), dvp, a);
                matvec_t_into(wq, dm, dqp, &mut tape.vec_dm);
                matvec_t_accum(wk, dm, dkp, &mut tape.vec_dm);
                matvec_t_accum(wv, dm, dvp, &mut tape.vec_dm);
                rmsnorm_bwd(
                    &x[pos * dm..(pos + 1) * dm],
                    g_attn,
                    tape.inv_attn[l][pos],
                    &tape.vec_dm,
                    &mut tape.vec_dm2,
                    seg.g_attn.of_mut(grads),
                );
                let dxp = &mut tape.dxs[pos * dm..(pos + 1) * dm];
                for (j, dj) in dxp.iter_mut().enumerate() {
                    *dj = tape.dmid[pos * dm + j] + tape.vec_dm2[j];
                }
            }
        }

        // ── Embedding lookup backward (tied with the output head) ──
        let de = self.params.embed.of_mut(grads);
        for pos in 0..t {
            let row = tape.tokens[pos] as usize * dm;
            axpy(1.0, &tape.dxs[pos * dm..(pos + 1) * dm], &mut de[row..row + dm]);
        }
        Ok(loss)
    }

    /// Greedy autoregressive answer: feed `prompt`, then argmax-extend
    /// for `n_answer` tokens (teacher-free — the trainer's own
    /// exact-cache accuracy metric).
    pub fn greedy_answer(
        &self,
        prompt: &[i32],
        n_answer: usize,
        tape: &mut Tape,
    ) -> Result<Vec<i32>> {
        let vocab = self.spec().vocab;
        let mut seq = prompt.to_vec();
        let mut out = Vec::with_capacity(n_answer);
        for _ in 0..n_answer {
            self.forward(&seq, tape)?;
            let next = argmax(tape.logits_at(seq.len() - 1, vocab)) as i32;
            out.push(next);
            seq.push(next);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err_vec;
    use crate::model::HostExecutor;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            vocab: 16,
            d_model: 8,
            n_heads: 1,
            n_layers: 2,
            d_head: 4,
            prefill_t: 16,
            cache_variants: vec![16],
            decode_batch: 0,
            train_accuracy: -1.0,
        }
    }

    fn loss_of(model: &TrainModel, tokens: &[i32], targets: &[(usize, i32)]) -> f64 {
        let mut tape = Tape::new();
        model.forward(tokens, &mut tape).unwrap();
        let vocab = model.spec().vocab;
        let mut loss = 0.0f64;
        for &(pos, target) in targets {
            let logits = tape.logits_at(pos, vocab);
            let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f64;
            for &x in logits {
                z += ((x - m) as f64).exp();
            }
            loss += z.ln() - (logits[target as usize] - m) as f64;
        }
        loss
    }

    #[test]
    fn forward_matches_host_executor_prefill() {
        // The trainer's forward and the serving prefill are the same
        // function of the same checkpoint.
        let host = HostExecutor::small(31);
        let model = TrainModel::from_checkpoint(&host.to_checkpoint()).unwrap();
        let tokens = [1, 7, 3, 0, 12, 5, 9];
        let pre = host.prefill(&tokens).unwrap();
        let mut tape = Tape::new();
        model.forward(&tokens, &mut tape).unwrap();
        let v = host.spec().vocab;
        for pos in 0..tokens.len() {
            let want = &pre.logits[pos * v..(pos + 1) * v];
            let err = rel_err_vec(tape.logits_at(pos, v), want);
            assert!(err < 1e-4, "pos {pos}: err={err}");
        }
    }

    #[test]
    fn batched_forward_matches_per_position_matvecs_bitwise() {
        // The batched projection sweeps must be *bit-identical* to the
        // per-position `matvec_into` loop they replaced: recompute every
        // recorded matvec from its recorded input (same op order —
        // matvec, then RoPE, then scale) and compare bit patterns.
        let bits = |s: &[f32]| s.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let model = TrainModel::init(tiny_spec(), 11).unwrap();
        let spec = model.spec().clone();
        let (dm, h, dh) = (spec.d_model, spec.n_heads, spec.d_head);
        let (d_ff, vocab, hd) = (spec.d_ff(), spec.vocab, spec.n_heads * spec.d_head);
        let q_scale = 1.0 / (dh as f32).sqrt();
        let tokens = [1, 3, 5, 2, 7, 4, 9];
        let t = tokens.len();
        let mut tape = Tape::new();
        model.forward(&tokens, &mut tape).unwrap();
        let p = model.params().data();
        let mut want = vec![0.0f32; hd.max(d_ff).max(vocab).max(dm)];
        for (l, seg) in model.params().layers.iter().enumerate() {
            for pos in 0..t {
                let a = &tape.a_norm[l][pos * dm..(pos + 1) * dm];
                matvec_into(seg.wq.of(p), dm, a, &mut want[..hd]);
                rope_inplace(&mut want[..hd], h, &model.rope, pos);
                for w in want[..hd].iter_mut() {
                    *w *= q_scale;
                }
                assert_eq!(bits(&want[..hd]), bits(&tape.q[l][pos * hd..(pos + 1) * hd]));
                matvec_into(seg.wk.of(p), dm, a, &mut want[..hd]);
                rope_inplace(&mut want[..hd], h, &model.rope, pos);
                assert_eq!(bits(&want[..hd]), bits(&tape.k[l][pos * hd..(pos + 1) * hd]));
                matvec_into(seg.wv.of(p), dm, a, &mut want[..hd]);
                assert_eq!(bits(&want[..hd]), bits(&tape.v[l][pos * hd..(pos + 1) * hd]));
                let att = &tape.att[l][pos * hd..(pos + 1) * hd];
                matvec_into(seg.wo.of(p), hd, att, &mut want[..dm]);
                for (j, w) in want[..dm].iter_mut().enumerate() {
                    *w = tape.xs[l][pos * dm + j] + *w;
                }
                assert_eq!(bits(&want[..dm]), bits(&tape.x_mid[l][pos * dm..(pos + 1) * dm]));
                let b = &tape.b_norm[l][pos * dm..(pos + 1) * dm];
                matvec_into(seg.w1.of(p), dm, b, &mut want[..d_ff]);
                let pre = &tape.ff_pre[l][pos * d_ff..(pos + 1) * d_ff];
                assert_eq!(bits(&want[..d_ff]), bits(pre));
            }
        }
        let embed = model.params().embed.of(p);
        for pos in 0..t {
            let hf = &tape.hfin[pos * dm..(pos + 1) * dm];
            matvec_into(embed, dm, hf, &mut want[..vocab]);
            assert_eq!(bits(&want[..vocab]), bits(tape.logits_at(pos, vocab)));
        }
    }

    #[test]
    fn backward_matches_finite_differences_on_every_parameter() {
        // Central differences over the full parameter arena — the one
        // test that certifies the hand-derived backward (RMSNorm, RoPE
        // transpose, softmax attention, SiLU, tied embeddings).
        let mut model = TrainModel::init(tiny_spec(), 3).unwrap();
        let tokens = [1, 3, 5, 2, 7, 4];
        let targets = [(2usize, 5i32), (4, 9), (5, 1)];
        let mut tape = Tape::new();
        model.forward(&tokens, &mut tape).unwrap();
        let mut grads = vec![0.0f32; model.params().len()];
        let loss = model.backward(&mut tape, &targets, &mut grads).unwrap();
        assert!(loss.is_finite() && loss > 0.0);

        let eps = 3e-3f32;
        for i in 0..model.params().len() {
            let orig = model.params().data()[i];
            model.params_mut().data_mut()[i] = orig + eps;
            let up = loss_of(&model, &tokens, &targets);
            model.params_mut().data_mut()[i] = orig - eps;
            let down = loss_of(&model, &tokens, &targets);
            model.params_mut().data_mut()[i] = orig;
            let numeric = ((up - down) / (2.0 * eps as f64)) as f32;
            let analytic = grads[i];
            let tol = 1e-2 + 0.06 * analytic.abs().max(numeric.abs());
            assert!(
                (analytic - numeric).abs() <= tol,
                "param {i}: analytic={analytic} numeric={numeric}"
            );
        }
    }

    #[test]
    fn backward_is_zero_without_targets() {
        let model = TrainModel::init(tiny_spec(), 1).unwrap();
        let mut tape = Tape::new();
        model.forward(&[1, 2, 3], &mut tape).unwrap();
        let mut grads = vec![0.0f32; model.params().len()];
        let loss = model.backward(&mut tape, &[], &mut grads).unwrap();
        assert_eq!(loss, 0.0);
        assert!(grads.iter().all(|&g| g == 0.0));
    }

    #[test]
    fn backward_rejects_bad_targets() {
        let model = TrainModel::init(tiny_spec(), 1).unwrap();
        let mut tape = Tape::new();
        model.forward(&[1, 2, 3], &mut tape).unwrap();
        let mut grads = vec![0.0f32; model.params().len()];
        assert!(model.backward(&mut tape, &[(9, 1)], &mut grads).is_err());
        assert!(model.backward(&mut tape, &[(1, 99)], &mut grads).is_err());
    }

    #[test]
    fn greedy_answer_is_deterministic() {
        let model = TrainModel::init(tiny_spec(), 5).unwrap();
        let mut tape = Tape::new();
        let a = model.greedy_answer(&[1, 2, 3], 2, &mut tape).unwrap();
        let b = model.greedy_answer(&[1, 2, 3], 2, &mut tape).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 2);
        assert!(a.iter().all(|&t| (0..16).contains(&t)));
    }

    #[test]
    fn forward_rejects_out_of_vocab() {
        let model = TrainModel::init(tiny_spec(), 1).unwrap();
        let mut tape = Tape::new();
        assert!(model.forward(&[99], &mut tape).is_err());
        assert!(model.forward(&[], &mut tape).is_err());
    }
}
