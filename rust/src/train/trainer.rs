//! Mini-batch trainer: fits the host transformer on the line-retrieval
//! workload with next-token cross-entropy masked to the answer tokens.
//!
//! Documents come from [`RetrievalSampler`] (the same generator the
//! serving harness evaluates on), with the line count drawn uniformly
//! per document from `[lines_min, lines_max]` so the model sees mixed
//! context lengths. Progress is measured the honest way — greedy
//! teacher-free decoding of held-out documents — and training stops
//! early once that accuracy reaches `target_accuracy`.

use super::model::{Tape, TrainModel};
use super::optim::{clip_grad_norm, OptimKind, Optimizer};
use crate::model::ModelSpec;
use crate::rng::{Pcg64, Rng};
use crate::workload::{RetrievalSampler, ANSWER_TOKENS};
use anyhow::Result;

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Minimum document length in lines.
    pub lines_min: usize,
    /// Maximum document length in lines.
    pub lines_max: usize,
    /// Documents per optimizer step.
    pub batch: usize,
    /// Maximum optimizer steps.
    pub steps: usize,
    /// Peak learning rate (linear warmup over `warmup` steps).
    pub lr: f32,
    /// Warmup steps.
    pub warmup: usize,
    /// Update rule.
    pub optimizer: OptimKind,
    /// SGD momentum (ignored by Adam).
    pub momentum: f32,
    /// Global gradient-norm clip (0 disables).
    pub clip: f32,
    /// Seed for init, document sampling and evaluation.
    pub seed: u64,
    /// Evaluate (and maybe early-stop) every N steps; 0 = only at end.
    pub eval_every: usize,
    /// Held-out documents per evaluation.
    pub eval_docs: usize,
    /// Stop once held-out greedy accuracy reaches this (0 = never).
    pub target_accuracy: f64,
    /// Print `train step=…` progress lines.
    pub log: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            lines_min: 2,
            lines_max: 4,
            batch: 16,
            steps: 5000,
            lr: 2e-3,
            warmup: 50,
            optimizer: OptimKind::Adam,
            momentum: 0.9,
            clip: 1.0,
            seed: 0,
            eval_every: 100,
            eval_docs: 32,
            target_accuracy: 0.95,
            log: false,
        }
    }
}

/// What a training run produced.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Optimizer steps actually taken (early stop may cut `steps`).
    pub steps: usize,
    /// Mean masked cross-entropy of the last step.
    pub final_loss: f64,
    /// Final held-out greedy exact-match accuracy.
    pub accuracy: f64,
}

/// The training loop.
pub struct Trainer {
    model: TrainModel,
    opt: Optimizer,
    cfg: TrainConfig,
    grads: Vec<f32>,
    tape: Tape,
    sampler: RetrievalSampler<Pcg64>,
    lines_rng: Pcg64,
    step: usize,
}

impl Trainer {
    /// Fresh model + optimizer for `spec` under `cfg`.
    pub fn new(spec: ModelSpec, cfg: TrainConfig) -> Result<Trainer> {
        anyhow::ensure!(cfg.lines_min >= 1 && cfg.lines_min <= cfg.lines_max, "bad line range");
        anyhow::ensure!(cfg.lines_max <= 100, "retrieval documents cap at 100 lines");
        anyhow::ensure!(cfg.batch >= 1, "batch must be at least 1");
        anyhow::ensure!(cfg.steps >= 1, "steps must be at least 1");
        let model = TrainModel::init(spec, cfg.seed)?;
        let grads = vec![0.0; model.params().len()];
        Ok(Trainer {
            opt: Optimizer::new(cfg.optimizer, cfg.lr, cfg.momentum),
            sampler: RetrievalSampler::new(Pcg64::seed_from_u64(cfg.seed ^ 0x7EA1_D0C5)),
            lines_rng: Pcg64::seed_from_u64(cfg.seed ^ 0x11E5),
            model,
            cfg,
            grads,
            tape: Tape::new(),
            step: 0,
        })
    }

    /// One optimizer step over a fresh mini-batch; returns the mean
    /// masked cross-entropy (nats per answer token).
    pub fn train_step(&mut self) -> Result<f64> {
        let span = self.cfg.lines_max - self.cfg.lines_min + 1;
        self.grads.fill(0.0);
        let mut loss = 0.0f64;
        let mut masked = 0usize;
        for _ in 0..self.cfg.batch {
            let n_lines = self.cfg.lines_min + self.lines_rng.index(span);
            let inst = self.sampler.sample(n_lines);
            let (prompt, answer) = inst.tokens();
            let mut seq = prompt;
            let prompt_len = seq.len();
            seq.extend_from_slice(&answer);
            let targets: Vec<(usize, i32)> =
                answer.iter().enumerate().map(|(i, &a)| (prompt_len - 1 + i, a)).collect();
            self.model.forward(&seq, &mut self.tape)?;
            loss += self.model.backward(&mut self.tape, &targets, &mut self.grads)?;
            masked += targets.len();
        }
        let scale = 1.0 / masked as f32;
        for g in self.grads.iter_mut() {
            *g *= scale;
        }
        clip_grad_norm(&mut self.grads, self.cfg.clip);
        // Linear warmup to the peak rate, then constant.
        let ramp = if self.cfg.warmup > 0 {
            ((self.step + 1) as f32 / self.cfg.warmup as f32).min(1.0)
        } else {
            1.0
        };
        self.opt.lr = self.cfg.lr * ramp;
        self.opt.step(self.model.params_mut().data_mut(), &self.grads);
        self.step += 1;
        Ok(loss / masked as f64)
    }

    /// Greedy teacher-free exact-match accuracy on `docs` held-out
    /// documents of `n_lines` lines, drawn from `seed` (a stream
    /// disjoint from the training sampler's).
    pub fn eval_accuracy(&mut self, docs: usize, n_lines: usize, seed: u64) -> Result<f64> {
        greedy_accuracy(&self.model, &mut self.tape, docs, n_lines, seed)
    }

    /// Run the full loop: step, periodically evaluate, early-stop at
    /// `target_accuracy`, and record the final accuracy in the model's
    /// spec (`train_accuracy`, carried into exported checkpoints).
    pub fn run(&mut self) -> Result<TrainReport> {
        let eval_seed = self.cfg.seed ^ 0x55AA_1234;
        let (docs, lines) = (self.cfg.eval_docs, self.cfg.lines_max);
        let mut loss = f64::NAN;
        let mut accuracy = 0.0;
        let mut evaluated_at = usize::MAX;
        while self.step < self.cfg.steps {
            loss = self.train_step()?;
            let due = self.cfg.eval_every > 0 && self.step % self.cfg.eval_every == 0;
            if due || self.step == self.cfg.steps {
                accuracy = self.eval_accuracy(docs, lines, eval_seed)?;
                evaluated_at = self.step;
                if self.cfg.log {
                    println!(
                        "train step={} loss={loss:.4} acc={accuracy:.3} lr={:.5}",
                        self.step, self.opt.lr
                    );
                }
                if self.cfg.target_accuracy > 0.0 && accuracy >= self.cfg.target_accuracy {
                    break;
                }
            }
        }
        if evaluated_at != self.step {
            accuracy = self.eval_accuracy(docs, lines, eval_seed)?;
        }
        self.model.params_mut().set_train_accuracy(accuracy);
        Ok(TrainReport { steps: self.step, final_loss: loss, accuracy })
    }

    /// Steps taken so far.
    pub fn steps_taken(&self) -> usize {
        self.step
    }

    /// The model being trained.
    pub fn model(&self) -> &TrainModel {
        &self.model
    }

    /// Consume into the trained model.
    pub fn into_model(self) -> TrainModel {
        self.model
    }
}

/// Greedy exact-match accuracy of `model` over `docs` fresh documents —
/// the trainer-side analog of the serving harness's exact-cache row.
pub fn greedy_accuracy(
    model: &TrainModel,
    tape: &mut Tape,
    docs: usize,
    n_lines: usize,
    seed: u64,
) -> Result<f64> {
    anyhow::ensure!(docs >= 1, "need at least one eval document");
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut correct = 0usize;
    for _ in 0..docs {
        let inst = sampler.sample(n_lines);
        let (prompt, answer) = inst.tokens();
        if model.greedy_answer(&prompt, ANSWER_TOKENS, tape)? == answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / docs as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dm: usize, heads: usize, dh: usize) -> ModelSpec {
        ModelSpec {
            vocab: crate::workload::VOCAB,
            d_model: dm,
            n_heads: heads,
            n_layers: 2,
            d_head: dh,
            prefill_t: 64,
            cache_variants: vec![64, 32],
            decode_batch: 0,
            train_accuracy: -1.0,
        }
    }

    fn cfg(steps: usize) -> TrainConfig {
        TrainConfig {
            lines_min: 2,
            lines_max: 2,
            batch: 4,
            steps,
            eval_every: 0,
            eval_docs: 8,
            target_accuracy: 0.0,
            ..Default::default()
        }
    }

    #[test]
    fn loss_decreases_within_a_few_steps() {
        let mut t = Trainer::new(spec(16, 2, 8), cfg(40)).unwrap();
        // Mini-batch losses are noisy draws; compare 5-step averages at
        // the start and end of the run.
        let mut losses = Vec::with_capacity(40);
        for _ in 0..40 {
            losses.push(t.train_step().unwrap());
        }
        assert!(losses.iter().all(|l| l.is_finite() && *l > 0.0));
        let head: f64 = losses[..5].iter().sum::<f64>() / 5.0;
        let tail: f64 = losses[35..].iter().sum::<f64>() / 5.0;
        assert!(tail < head, "loss did not decrease: {head:.4} → {tail:.4}");
        assert_eq!(t.steps_taken(), 40);
    }

    #[test]
    fn training_is_deterministic_by_seed() {
        let run = || {
            let mut t = Trainer::new(spec(16, 2, 8), cfg(5)).unwrap();
            for _ in 0..5 {
                t.train_step().unwrap();
            }
            t.into_model().params().data().to_vec()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn eval_accuracy_is_deterministic_and_bounded() {
        let mut t = Trainer::new(spec(16, 2, 8), cfg(1)).unwrap();
        let a = t.eval_accuracy(10, 2, 7).unwrap();
        let b = t.eval_accuracy(10, 2, 7).unwrap();
        assert_eq!(a, b);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn run_records_accuracy_in_spec() {
        let mut t = Trainer::new(spec(16, 2, 8), cfg(3)).unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.steps, 3);
        assert!(report.final_loss.is_finite());
        let acc = t.model().spec().train_accuracy;
        assert!((acc - report.accuracy).abs() < 1e-9);
    }

    #[test]
    fn rejects_degenerate_configs() {
        assert!(Trainer::new(spec(16, 2, 8), TrainConfig { batch: 0, ..cfg(1) }).is_err());
        let bad = TrainConfig { lines_min: 5, lines_max: 4, ..cfg(1) };
        assert!(Trainer::new(spec(16, 2, 8), bad).is_err());
        // steps: 0 would "train" nothing and export a random-init
        // checkpoint with a NaN loss; reject it like the other knobs.
        assert!(Trainer::new(spec(16, 2, 8), cfg(0)).is_err());
    }
}
