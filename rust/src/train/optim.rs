//! First-order optimizers over the flat parameter arena.
//!
//! Both update rules are elementwise sweeps over `(params, grads,
//! state)` — the payoff of keeping every weight in one contiguous
//! buffer ([`crate::train::ParamSet`]). State buffers are lazily sized
//! on the first step.

/// Which update rule an [`Optimizer`] applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    /// SGD with (optional) momentum.
    Sgd,
    /// Adam with bias correction.
    Adam,
}

impl std::str::FromStr for OptimKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "sgd" => Ok(OptimKind::Sgd),
            "adam" => Ok(OptimKind::Adam),
            other => anyhow::bail!("unknown optimizer {other:?} (sgd|adam)"),
        }
    }
}

/// SGD-with-momentum / Adam over flat buffers.
pub struct Optimizer {
    kind: OptimKind,
    /// Learning rate; mutable so schedules (warmup) can drive it.
    pub lr: f32,
    /// SGD momentum coefficient (ignored by Adam).
    pub momentum: f32,
    /// Adam β₁ / β₂ / ε.
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// First-moment / momentum buffer.
    m: Vec<f32>,
    /// Second-moment buffer (Adam only).
    v: Vec<f32>,
    /// Steps taken (Adam bias correction).
    t: u32,
}

impl Optimizer {
    /// SGD with momentum (`momentum = 0` is plain SGD).
    pub fn sgd(lr: f32, momentum: f32) -> Optimizer {
        Optimizer {
            kind: OptimKind::Sgd,
            lr,
            momentum,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    /// Adam with the standard (0.9, 0.999, 1e-8) moments.
    pub fn adam(lr: f32) -> Optimizer {
        Optimizer { kind: OptimKind::Adam, ..Optimizer::sgd(lr, 0.0) }
    }

    /// Build by kind (CLI plumbing).
    pub fn new(kind: OptimKind, lr: f32, momentum: f32) -> Optimizer {
        match kind {
            OptimKind::Sgd => Optimizer::sgd(lr, momentum),
            OptimKind::Adam => Optimizer::adam(lr),
        }
    }

    /// The update rule in use.
    pub fn kind(&self) -> OptimKind {
        self.kind
    }

    /// Apply one update: `params -= lr · direction(grads)`.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len(), "param/grad length mismatch");
        if self.m.len() != params.len() {
            self.m = vec![0.0; params.len()];
            if self.kind == OptimKind::Adam {
                self.v = vec![0.0; params.len()];
            }
        }
        match self.kind {
            OptimKind::Sgd => {
                for ((p, &g), m) in params.iter_mut().zip(grads).zip(&mut self.m) {
                    *m = self.momentum * *m + g;
                    *p -= self.lr * *m;
                }
            }
            OptimKind::Adam => {
                self.t += 1;
                let bc1 = 1.0 - self.beta1.powi(self.t as i32);
                let bc2 = 1.0 - self.beta2.powi(self.t as i32);
                for (((p, &g), m), v) in
                    params.iter_mut().zip(grads).zip(&mut self.m).zip(&mut self.v)
                {
                    *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                    *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                    let mhat = *m / bc1;
                    let vhat = *v / bc2;
                    *p -= self.lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
    }
}

/// Scale `grads` down so their global L2 norm is at most `max_norm`
/// (no-op when `max_norm <= 0`). Returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [f32], max_norm: f32) -> f64 {
    let norm = grads.iter().map(|&g| g as f64 * g as f64).sum::<f64>().sqrt();
    if max_norm > 0.0 && norm > max_norm as f64 {
        let scale = (max_norm as f64 / norm) as f32;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize ‖p − c‖² from zero; both rules must converge to c.
    fn converges(mut opt: Optimizer, steps: usize, tol: f32) {
        let c = [1.0f32, -2.0, 0.5, 3.0];
        let mut p = [0.0f32; 4];
        for _ in 0..steps {
            let g: Vec<f32> = p.iter().zip(&c).map(|(&pi, &ci)| 2.0 * (pi - ci)).collect();
            opt.step(&mut p, &g);
        }
        for (pi, ci) in p.iter().zip(&c) {
            assert!((pi - ci).abs() < tol, "{p:?} vs {c:?}");
        }
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        converges(Optimizer::sgd(0.1, 0.0), 200, 1e-3);
        converges(Optimizer::sgd(0.05, 0.9), 300, 1e-2);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        converges(Optimizer::adam(0.1), 800, 1e-2);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // Bias correction makes the first Adam update ≈ lr·sign(g).
        let mut opt = Optimizer::adam(0.01);
        let mut p = [0.0f32; 2];
        opt.step(&mut p, &[3.0, -0.5]);
        assert!((p[0] + 0.01).abs() < 1e-4, "{p:?}");
        assert!((p[1] - 0.01).abs() < 1e-4, "{p:?}");
    }

    #[test]
    fn clip_bounds_global_norm() {
        let mut g = [3.0f32, 4.0];
        let norm = clip_grad_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let after = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((after - 1.0).abs() < 1e-5);
        // No-op when under the bound or disabled.
        let mut h = [0.3f32, 0.4];
        clip_grad_norm(&mut h, 1.0);
        assert_eq!(h, [0.3, 0.4]);
        let mut k = [3.0f32, 4.0];
        clip_grad_norm(&mut k, 0.0);
        assert_eq!(k, [3.0, 4.0]);
    }

    #[test]
    fn kind_parses_from_str() {
        assert_eq!("sgd".parse::<OptimKind>().unwrap(), OptimKind::Sgd);
        assert_eq!("adam".parse::<OptimKind>().unwrap(), OptimKind::Adam);
        assert!("bogus".parse::<OptimKind>().is_err());
    }
}
