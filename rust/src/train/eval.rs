//! Table-1-shaped accuracy evaluation: decode held-out retrieval
//! documents through the serving engine under **every cache policy at a
//! matched per-head token budget**, and report exact-match accuracy per
//! policy.
//!
//! Every policy answers the *same* documents (the sampler is re-seeded
//! per policy), so rows differ only by what each cache retains. The
//! exact row is the uncompressed reference; compressed policies share
//! one budget knob (`kvcache::build_policy`'s cross-policy matching).

use crate::coordinator::{Engine, EngineConfig, Request, StepExecutor};
use crate::rng::Pcg64;
use crate::workload::{seq_len_for_lines, RetrievalSampler};
use anyhow::Result;

/// One policy's row of the accuracy table.
#[derive(Debug, Clone)]
pub struct PolicyAccuracy {
    /// Cache policy name.
    pub policy: String,
    /// Exactly-matched answers.
    pub correct: usize,
    /// Documents evaluated.
    pub total: usize,
    /// Mean retained cache bytes per sequence at completion.
    pub mean_cache_bytes: f64,
}

impl PolicyAccuracy {
    /// Exact-match accuracy in [0, 1].
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

/// What to evaluate.
#[derive(Debug, Clone)]
pub struct EvalConfig {
    /// Held-out documents per policy.
    pub questions: usize,
    /// Lines per document.
    pub n_lines: usize,
    /// Per-head token budget for the compressed policies.
    pub budget: usize,
    /// SubGen cluster threshold δ.
    pub delta: f32,
    /// Document stream seed (disjoint from training streams).
    pub seed: u64,
    /// KV-cache storage encoding for every decoded sequence
    /// (`"f32"`/`"f16"`/`"int8"`); the string is forwarded to
    /// [`EngineConfig::kv_dtype`] untouched, so the evaluator stays as
    /// encoding-blind as the engine.
    pub kv_dtype: String,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            questions: 50,
            n_lines: 8,
            budget: 48,
            delta: 4.0,
            seed: 0x5EED_E7A1,
            kv_dtype: "f32".into(),
        }
    }
}

/// Decode `cfg.questions` documents through `exec` once per policy and
/// return the per-policy rows, in the given policy order.
pub fn evaluate_policies<E: StepExecutor>(
    exec: &E,
    policies: &[&str],
    cfg: &EvalConfig,
) -> Result<Vec<PolicyAccuracy>> {
    anyhow::ensure!(cfg.questions >= 1, "need at least one question");
    anyhow::ensure!((1..=100).contains(&cfg.n_lines), "n_lines must be 1..=100");
    let prompt_len = seq_len_for_lines(cfg.n_lines) - crate::workload::ANSWER_TOKENS;
    anyhow::ensure!(
        prompt_len <= exec.spec().prefill_t,
        "prompt of {} tokens exceeds prefill_t {}",
        prompt_len,
        exec.spec().prefill_t
    );
    let mut rows = Vec::with_capacity(policies.len());
    for &policy in policies {
        let mut engine = Engine::new(
            exec,
            EngineConfig {
                queue_capacity: cfg.questions + 1,
                kv_dtype: cfg.kv_dtype.clone(),
                ..Default::default()
            },
        );
        // Same seed per policy ⇒ every row answers identical documents.
        let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(cfg.seed));
        let mut expected = Vec::with_capacity(cfg.questions);
        for id in 0..cfg.questions {
            let inst = sampler.sample(cfg.n_lines);
            let (prompt, answer) = inst.tokens();
            let max_new = answer.len();
            expected.push(answer);
            let accepted = engine.submit(Request {
                id: id as u64,
                session_id: None,
                prompt,
                max_new,
                policy: policy.to_string(),
                budget: cfg.budget,
                delta: cfg.delta,
                deadline: None,
                class: crate::coordinator::RequestClass::Interactive,
            });
            anyhow::ensure!(accepted, "engine rejected eval request {id}");
        }
        engine.run_to_completion()?;
        let responses = engine.take_responses();
        anyhow::ensure!(responses.len() == cfg.questions, "{policy}: lost responses");
        let mut correct = 0usize;
        let mut bytes = 0u64;
        for r in &responses {
            if r.tokens == expected[r.id as usize] {
                correct += 1;
            }
            bytes += r.cache_bytes as u64;
        }
        rows.push(PolicyAccuracy {
            policy: policy.to_string(),
            correct,
            total: cfg.questions,
            mean_cache_bytes: bytes as f64 / cfg.questions as f64,
        });
    }
    Ok(rows)
}

/// Render budget-sweep results as `BENCH_query.json`-style JSON for
/// trend tracking (no `*_ns` keys — the perf gate only guards those).
pub fn accuracy_json(
    sweeps: &[(usize, Vec<PolicyAccuracy>)],
    n_lines: usize,
    questions: usize,
    delta: f32,
    train_accuracy: f64,
) -> String {
    let mut out = String::from("{\n  \"bench\": \"eval_retrieval\",\n");
    out.push_str(&format!(
        "  \"config\": {{\"n_lines\": {n_lines}, \"questions\": {questions}, \
         \"delta\": {delta}, \"train_accuracy\": {train_accuracy:.4}}},\n"
    ));
    out.push_str("  \"budgets\": [\n");
    for (i, (budget, rows)) in sweeps.iter().enumerate() {
        let acc: Vec<String> =
            rows.iter().map(|r| format!("\"{}\": {:.4}", r.policy, r.accuracy())).collect();
        let bytes: Vec<String> = rows
            .iter()
            .map(|r| format!("\"{}\": {:.0}", r.policy, r.mean_cache_bytes))
            .collect();
        let comma = if i + 1 < sweeps.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"budget\": {budget}, \"accuracy\": {{{}}}, \"cache_bytes\": {{{}}}}}{comma}\n",
            acc.join(", "),
            bytes.join(", ")
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// [`accuracy_json`] with a KV-encoding dimension: one `budgets` entry
/// per (kv_dtype, budget) pair, so trend lines can track quantized
/// accuracy against the f32 reference in the same file.
pub fn accuracy_json_encoded(
    sweeps: &[(String, usize, Vec<PolicyAccuracy>)],
    n_lines: usize,
    questions: usize,
    delta: f32,
    train_accuracy: f64,
) -> String {
    let mut out = String::from("{\n  \"bench\": \"eval_retrieval\",\n");
    out.push_str(&format!(
        "  \"config\": {{\"n_lines\": {n_lines}, \"questions\": {questions}, \
         \"delta\": {delta}, \"train_accuracy\": {train_accuracy:.4}}},\n"
    ));
    out.push_str("  \"budgets\": [\n");
    for (i, (dtype, budget, rows)) in sweeps.iter().enumerate() {
        let acc: Vec<String> =
            rows.iter().map(|r| format!("\"{}\": {:.4}", r.policy, r.accuracy())).collect();
        let bytes: Vec<String> = rows
            .iter()
            .map(|r| format!("\"{}\": {:.0}", r.policy, r.mean_cache_bytes))
            .collect();
        let comma = if i + 1 < sweeps.len() { "," } else { "" };
        out.push_str(&format!(
            "    {{\"kv_dtype\": \"{dtype}\", \"budget\": {budget}, \"accuracy\": {{{}}}, \
             \"cache_bytes\": {{{}}}}}{comma}\n",
            acc.join(", "),
            bytes.join(", ")
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::POLICY_NAMES;
    use crate::model::HostExecutor;

    #[test]
    fn evaluates_every_policy_on_identical_documents() {
        // An untrained model scores near zero, but the harness contract
        // holds: one row per policy, all totals equal, deterministic.
        let exec = HostExecutor::small(3);
        let cfg = EvalConfig { questions: 5, n_lines: 3, budget: 16, ..Default::default() };
        let rows = evaluate_policies(&exec, &POLICY_NAMES, &cfg).unwrap();
        assert_eq!(rows.len(), POLICY_NAMES.len());
        for (r, &name) in rows.iter().zip(&POLICY_NAMES) {
            assert_eq!(r.policy, name);
            assert_eq!(r.total, 5);
            assert!(r.correct <= 5);
            assert!(r.mean_cache_bytes > 0.0);
            assert!((0.0..=1.0).contains(&r.accuracy()));
        }
        let again = evaluate_policies(&exec, &POLICY_NAMES, &cfg).unwrap();
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(a.correct, b.correct);
        }
        // Exact retains the most; compressed rows must not exceed it.
        let exact = &rows[0];
        for r in &rows[1..] {
            assert!(r.mean_cache_bytes <= exact.mean_cache_bytes + 1e-6, "{}", r.policy);
        }
    }

    #[test]
    fn rejects_prompts_beyond_prefill() {
        let exec = HostExecutor::small(3); // prefill_t = 64
        let cfg = EvalConfig { questions: 1, n_lines: 20, budget: 16, ..Default::default() };
        assert!(evaluate_policies(&exec, &["exact"], &cfg).is_err());
    }

    #[test]
    fn json_contains_every_policy_and_budget() {
        let row = |policy: &str, correct: usize, bytes: f64| PolicyAccuracy {
            policy: policy.into(),
            correct,
            total: 10,
            mean_cache_bytes: bytes,
        };
        let rows = vec![row("exact", 9, 1024.0), row("subgen", 8, 512.0)];
        let json = accuracy_json(&[(32, rows.clone()), (64, rows)], 8, 10, 4.0, 0.95);
        assert!(json.contains("\"bench\": \"eval_retrieval\""));
        assert!(json.contains("\"budget\": 32"));
        assert!(json.contains("\"budget\": 64"));
        assert!(json.contains("\"exact\": 0.9000"));
        assert!(json.contains("\"subgen\": 0.8000"));
        assert!(json.contains("\"train_accuracy\": 0.9500"));
        assert!(!json.contains("_ns"), "accuracy JSON must not trip the perf gate");
    }
}
