//! Exact t-SNE (van der Maaten & Hinton 2008) — the visualization
//! behind Figure 1, built from scratch.
//!
//! O(n²) affinities are fine at Figure-1 scale (n ≈ 1024 cached keys).
//! Perplexity is calibrated per point by bisection on the conditional
//! distribution entropy; the embedding is optimized by gradient descent
//! with momentum and early exaggeration, the standard recipe.

use crate::rng::{Pcg64, Rng};
use crate::tensor::{dist_sq, Tensor};

/// t-SNE hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct TsneConfig {
    /// Target perplexity of the conditional distributions.
    pub perplexity: f64,
    /// Gradient-descent iterations.
    pub iters: usize,
    /// Learning rate (η).
    pub learning_rate: f64,
    /// Early-exaggeration factor applied for the first quarter of iters.
    pub exaggeration: f64,
    /// RNG seed for the initial embedding.
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        Self { perplexity: 30.0, iters: 300, learning_rate: 100.0, exaggeration: 8.0, seed: 0 }
    }
}

/// Embed `points` (rows) into 2-D. Returns an (n × 2) tensor.
pub fn tsne(points: &Tensor, cfg: &TsneConfig) -> Tensor {
    let n = points.rows();
    assert!(n >= 4, "t-SNE needs at least 4 points");
    let p = joint_affinities(points, cfg.perplexity);

    // Init: small gaussian.
    let mut rng = Pcg64::seed_from_u64(cfg.seed);
    let mut y: Vec<[f64; 2]> =
        (0..n).map(|_| [rng.gaussian() * 1e-2, rng.gaussian() * 1e-2]).collect();
    let mut vel = vec![[0.0f64; 2]; n];
    let exag_until = cfg.iters / 4;

    let mut q = vec![0.0f64; n * n];
    let mut grad = vec![[0.0f64; 2]; n];
    for it in 0..cfg.iters {
        // Student-t affinities in embedding space.
        let mut qsum = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = y[i][0] - y[j][0];
                let dy = y[i][1] - y[j][1];
                let w = 1.0 / (1.0 + dx * dx + dy * dy);
                q[i * n + j] = w;
                q[j * n + i] = w;
                qsum += 2.0 * w;
            }
        }
        let exag = if it < exag_until { cfg.exaggeration } else { 1.0 };
        for g in grad.iter_mut() {
            *g = [0.0, 0.0];
        }
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let w = q[i * n + j];
                let pij = exag * p[i * n + j];
                let qij = (w / qsum).max(1e-12);
                let mult = 4.0 * (pij - qij) * w;
                grad[i][0] += mult * (y[i][0] - y[j][0]);
                grad[i][1] += mult * (y[i][1] - y[j][1]);
            }
        }
        let momentum = if it < exag_until { 0.5 } else { 0.8 };
        for i in 0..n {
            for d in 0..2 {
                vel[i][d] = momentum * vel[i][d] - cfg.learning_rate * grad[i][d];
                y[i][d] += vel[i][d];
            }
        }
        // Re-center to remove drift.
        let (mut cx, mut cy) = (0.0, 0.0);
        for yi in &y {
            cx += yi[0];
            cy += yi[1];
        }
        cx /= n as f64;
        cy /= n as f64;
        for yi in y.iter_mut() {
            yi[0] -= cx;
            yi[1] -= cy;
        }
    }

    let mut out = Tensor::zeros(0, 2);
    for yi in &y {
        out.push_row(&[yi[0] as f32, yi[1] as f32]);
    }
    out
}

/// Symmetrized joint affinities P with per-point bandwidth calibrated to
/// the target perplexity (row-major n×n, diagonal zero, sums to 1).
fn joint_affinities(points: &Tensor, perplexity: f64) -> Vec<f64> {
    let n = points.rows();
    let target_h = perplexity.ln();
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = dist_sq(points.row(i), points.row(j)) as f64;
            d2[i * n + j] = d;
            d2[j * n + i] = d;
        }
    }
    let mut p = vec![0.0f64; n * n];
    for i in 0..n {
        // Bisect beta = 1/(2σ²) to hit the target entropy.
        let (mut lo, mut hi) = (1e-12f64, 1e12f64);
        let mut beta = 1.0f64;
        for _ in 0..64 {
            let mut sum = 0.0;
            let mut sum_dp = 0.0;
            for j in 0..n {
                if j == i {
                    continue;
                }
                let e = (-beta * d2[i * n + j]).exp();
                sum += e;
                sum_dp += e * d2[i * n + j];
            }
            if sum <= 0.0 {
                hi = beta;
                beta = 0.5 * (lo + hi);
                continue;
            }
            // Entropy H = ln(sum) + beta * E[d²].
            let h = sum.ln() + beta * sum_dp / sum;
            if (h - target_h).abs() < 1e-5 {
                break;
            }
            if h > target_h {
                lo = beta;
            } else {
                hi = beta;
            }
            beta = 0.5 * (lo + hi);
        }
        let mut sum = 0.0;
        for j in 0..n {
            if j != i {
                let e = (-beta * d2[i * n + j]).exp();
                p[i * n + j] = e;
                sum += e;
            }
        }
        if sum > 0.0 {
            for j in 0..n {
                p[i * n + j] /= sum;
            }
        }
    }
    // Symmetrize and normalize.
    let mut joint = vec![0.0f64; n * n];
    let norm = 1.0 / (2.0 * n as f64);
    for i in 0..n {
        for j in 0..n {
            joint[i * n + j] = (p[i * n + j] + p[j * n + i]) * norm;
        }
    }
    joint
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, Rng};

    fn two_blobs(n_per: usize, sep: f32, seed: u64) -> Tensor {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut t = Tensor::zeros(0, 6);
        for b in 0..2 {
            for _ in 0..n_per {
                let p: Vec<f32> = (0..6)
                    .map(|_| b as f32 * sep + rng.gaussian32(0.0, 0.2))
                    .collect();
                t.push_row(&p);
            }
        }
        t
    }

    #[test]
    fn separates_two_blobs() {
        let pts = two_blobs(20, 8.0, 1);
        let cfg = TsneConfig { perplexity: 8.0, iters: 200, ..Default::default() };
        let y = tsne(&pts, &cfg);
        // Mean embedding of each blob should be far apart relative to
        // the within-blob spread.
        let mean = |lo: usize, hi: usize| -> [f32; 2] {
            let mut m = [0.0f32; 2];
            for i in lo..hi {
                m[0] += y.get(i, 0);
                m[1] += y.get(i, 1);
            }
            [m[0] / (hi - lo) as f32, m[1] / (hi - lo) as f32]
        };
        let m0 = mean(0, 20);
        let m1 = mean(20, 40);
        let between = ((m0[0] - m1[0]).powi(2) + (m0[1] - m1[1]).powi(2)).sqrt();
        let mut within = 0.0f32;
        for i in 0..20 {
            within +=
                ((y.get(i, 0) - m0[0]).powi(2) + (y.get(i, 1) - m0[1]).powi(2)).sqrt();
        }
        within /= 20.0;
        assert!(between > 2.0 * within, "between={between} within={within}");
    }

    #[test]
    fn affinities_are_normalized() {
        let pts = two_blobs(10, 4.0, 2);
        let p = joint_affinities(&pts, 5.0);
        let total: f64 = p.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "total={total}");
        // Diagonal zero, symmetric.
        let n = 20;
        for i in 0..n {
            assert_eq!(p[i * n + i], 0.0);
            for j in 0..n {
                assert!((p[i * n + j] - p[j * n + i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn output_is_centered() {
        let pts = two_blobs(10, 4.0, 3);
        let y = tsne(&pts, &TsneConfig { iters: 50, ..Default::default() });
        let mut c = [0.0f32; 2];
        for i in 0..y.rows() {
            c[0] += y.get(i, 0);
            c[1] += y.get(i, 1);
        }
        assert!(c[0].abs() / (y.rows() as f32) < 1e-3);
        assert!(c[1].abs() / (y.rows() as f32) < 1e-3);
    }
}
