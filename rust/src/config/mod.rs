//! Typed experiment/serving configuration + a TOML-subset parser.
//!
//! The parser covers the subset this repo writes: `[section]` headers,
//! `key = value` with string/int/float/bool values, `#` comments. Nested
//! tables and arrays are out of scope (configs here are flat sections).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A scalar config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// Parsed config: section -> key -> value. Top-of-file keys live in the
/// "" section.
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

/// Parse error with line number.
#[derive(Debug, Clone)]
pub struct ParseError {
    /// 1-based line.
    pub line: usize,
    /// Description.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Config {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Config, ParseError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno + 1,
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| ParseError {
                line: lineno + 1,
                message: format!("expected key = value, got {line:?}"),
            })?;
            let value = parse_value(v.trim()).map_err(|m| ParseError {
                line: lineno + 1,
                message: m,
            })?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), value);
        }
        Ok(cfg)
    }

    /// Load from a file.
    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    /// Raw value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// String lookup with default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        match self.get(section, key) {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => v.to_string(),
            None => default.to_string(),
        }
    }

    /// Integer lookup with default (accepts float-typed whole numbers).
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        match self.get(section, key) {
            Some(Value::Int(i)) => *i,
            Some(Value::Float(x)) if x.fract() == 0.0 => *x as i64,
            _ => default,
        }
    }

    /// Float lookup with default (accepts ints).
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        match self.get(section, key) {
            Some(Value::Float(x)) => *x,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    /// Bool lookup with default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    /// Set a value programmatically.
    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }

    /// Section names.
    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    /// Serialize back to TOML text.
    pub fn to_toml(&self) -> String {
        let mut out = String::new();
        if let Some(root) = self.sections.get("") {
            for (k, v) in root {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        for (name, table) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{name}]\n"));
            for (k, v) in table {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top comment
name = "subgen"
steps = 500

[policy]
kind = "subgen"   # inline comment
delta = 0.5
window = 64
enabled = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "name", ""), "subgen");
        assert_eq!(c.int_or("", "steps", 0), 500);
        assert_eq!(c.str_or("policy", "kind", ""), "subgen");
        assert!((c.float_or("policy", "delta", 0.0) - 0.5).abs() < 1e-12);
        assert_eq!(c.int_or("policy", "window", 0), 64);
        assert!(c.bool_or("policy", "enabled", false));
    }

    #[test]
    fn missing_keys_fall_back() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("x", "y", 42), 42);
        assert_eq!(c.str_or("x", "y", "z"), "z");
    }

    #[test]
    fn error_has_line_number() {
        let err = Config::parse("a = 1\nbogus line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn hash_in_string_kept() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str_or("", "s", ""), "a#b");
    }

    #[test]
    fn roundtrip() {
        let c = Config::parse(SAMPLE).unwrap();
        let text = c.to_toml();
        let c2 = Config::parse(&text).unwrap();
        assert_eq!(c2.int_or("policy", "window", 0), 64);
        assert_eq!(c2.str_or("", "name", ""), "subgen");
    }

    #[test]
    fn int_float_coercion() {
        let c = Config::parse("x = 3\ny = 4.0").unwrap();
        assert!((c.float_or("", "x", 0.0) - 3.0).abs() < 1e-12);
        assert_eq!(c.int_or("", "y", 0), 4);
    }
}
