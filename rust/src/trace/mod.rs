//! Low-overhead structured request tracing: the per-worker **flight
//! recorder** plus its exporters.
//!
//! Every request's lifecycle — submit → queue → admit → prefill
//! chunk(s) → decode ticks → snapshot → preemption →
//! done/expired/overloaded — is recorded as fixed-size binary events
//! into a lock-free ring buffer owned by the worker ([`FlightRecorder`]).
//! The decode hot path performs **zero allocation** per event: recording
//! is four relaxed atomic stores plus one release store into a
//! preallocated slot. When the buffer wraps, the oldest events are
//! overwritten (and counted in [`FlightRecorder::dropped`]) — exactly
//! the semantics wanted for crash forensics, where the *last* events
//! before a death matter most.
//!
//! # Event schema
//!
//! One event is five 64-bit words (40 bytes):
//!
//! | field     | meaning                                                    |
//! |-----------|------------------------------------------------------------|
//! | `t_us`    | monotonic microseconds since the recorder's epoch          |
//! | `session` | request id (`Request::id`); 0 for worker-scoped events     |
//! | `kind`    | [`EventKind`] discriminant                                 |
//! | `a`       | kind-specific payload (see below)                          |
//! | `b`       | kind-specific payload (see below)                          |
//!
//! Per-kind payloads:
//!
//! | kind                          | `a`                          | `b`                                 |
//! |-------------------------------|------------------------------|-------------------------------------|
//! | [`EventKind::Submit`]         | prompt length                | `max_new`                           |
//! | [`EventKind::Admit`]          | queue wait (µs)              | prompt length                       |
//! | [`EventKind::PrefillChunk`]   | chunk duration (ns)          | tokens in the chunk                 |
//! | [`EventKind::DecodeTick`]     | tick duration (ns)           | batch size (sequences this tick)    |
//! | [`EventKind::Snapshot`]       | tick number                  | generated tokens so far             |
//! | [`EventKind::Preempt`]        | prompt tokens already done   | prompt length                       |
//! | [`EventKind::Done`]           | total latency (µs)           | generated tokens                    |
//! | [`EventKind::Expired`]        | 0                            | 0                                   |
//! | [`EventKind::Overloaded`]     | aggregate outstanding work   | shed watermark                      |
//! | [`EventKind::CacheTelemetry`] | cache bytes                  | clusters (hi 32) \| reservoir (lo)  |
//! | [`EventKind::ProbeError`]     | layer (hi 32) \| head (lo)   | `f64::to_bits` of the measured error|
//! | [`EventKind::PageIn`]         | pages recalled from disk     | bytes recalled                      |
//! | [`EventKind::PageOut`]        | pages spilled to disk        | bytes spilled                       |
//!
//! `DecodeTick` and `CacheTelemetry` are *per-tick* classes and honor
//! the sampling rate ([`FlightRecorder::sample_every`]); lifecycle
//! events (everything else) are always recorded so request summaries
//! stay complete even under heavy sampling.
//!
//! # Exporters
//!
//! [`chrome_trace`] renders tracks of events as Chrome trace-event JSON
//! (open in Perfetto or `chrome://tracing`): one process ("track") per
//! worker, one thread lane per session, counter tracks for cache
//! telemetry. [`request_summaries`] folds events into per-request
//! [`RequestSummary`] rows (`queued_us`, `prefill_chunks`,
//! `preemptions`, `ticks`, `max_batch`, outcome) for human-readable
//! reports.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// What happened. Discriminants are stable (they appear in flight
/// recorder dumps on disk); append new kinds, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Request entered the engine's run queue.
    Submit = 0,
    /// Request left the queue and was admitted (prefill begins).
    Admit = 1,
    /// One chunked-prefill slice executed.
    PrefillChunk = 2,
    /// One decode tick advanced this session (or, for `session == 0`,
    /// the worker's whole tick).
    DecodeTick = 3,
    /// A recovery snapshot of this session was published.
    Snapshot = 4,
    /// An in-flight prefill was preempted by decode TPOT debt.
    Preempt = 5,
    /// Terminal: the request completed.
    Done = 6,
    /// Terminal: the request was dropped past its deadline.
    Expired = 7,
    /// Terminal: the router shed the request before dispatch.
    Overloaded = 8,
    /// Per-tick cache-policy telemetry sample (see
    /// [`crate::kvcache::CacheTelemetry`]).
    CacheTelemetry = 9,
    /// Measured estimator error for one (layer, head) from the
    /// exact-attention host probe.
    ProbeError = 10,
    /// Spilled KV pages were recalled from disk to satisfy a pin
    /// (payload: pages, bytes).
    PageIn = 11,
    /// Cold KV pages were evicted from the pool and spilled to disk
    /// (payload: pages, bytes).
    PageOut = 12,
}

impl EventKind {
    /// Stable lowercase name (used as the Chrome trace event name and
    /// in text summaries).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Admit => "admit",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::DecodeTick => "decode_tick",
            EventKind::Snapshot => "snapshot",
            EventKind::Preempt => "preempt",
            EventKind::Done => "done",
            EventKind::Expired => "expired",
            EventKind::Overloaded => "overloaded",
            EventKind::CacheTelemetry => "cache_telemetry",
            EventKind::ProbeError => "probe_error",
            EventKind::PageIn => "page_in",
            EventKind::PageOut => "page_out",
        }
    }

    fn from_u64(v: u64) -> Option<EventKind> {
        Some(match v {
            0 => EventKind::Submit,
            1 => EventKind::Admit,
            2 => EventKind::PrefillChunk,
            3 => EventKind::DecodeTick,
            4 => EventKind::Snapshot,
            5 => EventKind::Preempt,
            6 => EventKind::Done,
            7 => EventKind::Expired,
            8 => EventKind::Overloaded,
            9 => EventKind::CacheTelemetry,
            10 => EventKind::ProbeError,
            11 => EventKind::PageIn,
            12 => EventKind::PageOut,
            _ => return None,
        })
    }
}

/// One decoded flight-recorder event (see the module docs for the
/// per-kind payload schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Monotonic microseconds since the recorder's epoch.
    pub t_us: u64,
    /// Request id (0 for worker-scoped events).
    pub session: u64,
    /// Event kind.
    pub kind: EventKind,
    /// Kind-specific payload.
    pub a: u64,
    /// Kind-specific payload.
    pub b: u64,
}

/// One preallocated ring slot. `seq` is written last with `Release`
/// (and read first with `Acquire`), so a reader that observes `seq > 0`
/// sees the slot's fields from *some* completed write. A concurrent
/// wrap can still hand a reader a newer event than `seq` promised —
/// harmless for forensics, where the dump is taken after the worker is
/// fenced or dead and the writer has stopped.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    t_us: AtomicU64,
    session: AtomicU64,
    kind: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

/// Lock-free fixed-capacity ring buffer of [`TraceEvent`]s — the
/// per-worker flight recorder. Writers never allocate and never block;
/// the ring keeps the newest `capacity` events.
pub struct FlightRecorder {
    slots: Vec<Slot>,
    /// Total events ever written (next slot = `head % capacity`).
    head: AtomicU64,
    sample_every: u64,
    epoch: Instant,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.head.load(Ordering::Relaxed))
            .field("sample_every", &self.sample_every)
            .finish()
    }
}

impl FlightRecorder {
    /// Ring of `capacity` slots (min 16). `sample_every = n` records 1
    /// of every `n` per-tick events (`DecodeTick`/`CacheTelemetry`);
    /// 0 is treated as 1 (record every tick).
    pub fn new(capacity: usize, sample_every: u64) -> Self {
        let capacity = capacity.max(16);
        Self {
            slots: (0..capacity).map(|_| Slot::default()).collect(),
            head: AtomicU64::new(0),
            sample_every: sample_every.max(1),
            epoch: Instant::now(),
        }
    }

    /// Slots in the ring.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Per-tick sampling rate (1 = every tick).
    pub fn sample_every(&self) -> u64 {
        self.sample_every
    }

    /// Whether per-tick events should be recorded for tick `n`.
    #[inline]
    pub fn tick_sampled(&self, n: u64) -> bool {
        n % self.sample_every == 0
    }

    /// Total events recorded since construction (including overwritten
    /// ones).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events lost to ring wrap-around.
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Monotonic microseconds since this recorder's epoch.
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros().min(u128::from(u64::MAX)) as u64
    }

    /// Record one event. Lock-free, allocation-free: one `fetch_add`
    /// and five stores into a preallocated slot.
    #[inline]
    pub fn record(&self, kind: EventKind, session: u64, a: u64, b: u64) {
        let n = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        slot.t_us.store(self.now_us(), Ordering::Relaxed);
        slot.session.store(session, Ordering::Relaxed);
        slot.kind.store(kind as u64, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(n + 1, Ordering::Release);
    }

    /// Decode the ring's current contents, oldest first. Intended for
    /// export/forensics after the writer has quiesced (fenced worker,
    /// finished run); concurrent writes can skew ordering near the head
    /// but never corrupt an individual slot's invariants.
    pub fn events(&self) -> Vec<TraceEvent> {
        let head = self.head.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let start = head.saturating_sub(cap);
        let mut out = Vec::with_capacity((head - start) as usize);
        for n in start..head {
            let slot = &self.slots[(n % cap) as usize];
            if slot.seq.load(Ordering::Acquire) == 0 {
                continue;
            }
            let Some(kind) = EventKind::from_u64(slot.kind.load(Ordering::Relaxed)) else {
                continue;
            };
            out.push(TraceEvent {
                t_us: slot.t_us.load(Ordering::Relaxed),
                session: slot.session.load(Ordering::Relaxed),
                kind,
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            });
        }
        out.sort_by_key(|e| e.t_us);
        out
    }
}

/// Render event tracks as Chrome trace-event JSON (the `traceEvents`
/// array format Perfetto and `chrome://tracing` load directly).
///
/// Each `(label, events)` pair becomes one process (`pid` = index,
/// named by a `process_name` metadata record) — one track per worker.
/// Within a track, `tid` is the session id, so every session gets its
/// own lane. Span kinds (`decode_tick`, `prefill_chunk`) emit complete
/// (`"ph":"X"`) events with real durations; lifecycle kinds emit
/// instants (`"ph":"i"`); cache telemetry emits counter (`"ph":"C"`)
/// series (`cache_bytes`, `cache_clusters`, `cache_reservoir`).
pub fn chrome_trace(tracks: &[(String, Vec<TraceEvent>)]) -> String {
    let mut s = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |s: &mut String, item: String| {
        if !std::mem::take(&mut first) {
            s.push(',');
        }
        s.push_str(&item);
    };
    for (pid, (label, events)) in tracks.iter().enumerate() {
        push(
            &mut s,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
                 \"args\":{{\"name\":\"{}\"}}}}",
                escape_json(label)
            ),
        );
        for e in events {
            let name = e.kind.name();
            let item = match e.kind {
                EventKind::DecodeTick | EventKind::PrefillChunk => {
                    // `a` is the duration in ns; the event was recorded
                    // at its end, so the span starts dur earlier.
                    let dur_us = (e.a / 1_000).max(1);
                    let ts = e.t_us.saturating_sub(dur_us);
                    let (k, v) = match e.kind {
                        EventKind::DecodeTick => ("batch", e.b),
                        _ => ("tokens", e.b),
                    };
                    format!(
                        "{{\"name\":\"{name}\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur_us},\
                         \"pid\":{pid},\"tid\":{},\"args\":{{\"{k}\":{v}}}}}",
                        e.session
                    )
                }
                EventKind::CacheTelemetry => format!(
                    "{{\"name\":\"cache\",\"ph\":\"C\",\"ts\":{},\"pid\":{pid},\"tid\":0,\
                     \"args\":{{\"cache_bytes\":{},\"cache_clusters\":{},\
                     \"cache_reservoir\":{}}}}}",
                    e.t_us,
                    e.a,
                    e.b >> 32,
                    e.b & 0xFFFF_FFFF
                ),
                EventKind::ProbeError => format!(
                    "{{\"name\":\"{name}\",\"ph\":\"i\",\"ts\":{},\"pid\":{pid},\"tid\":{},\
                     \"s\":\"t\",\"args\":{{\"layer\":{},\"head\":{},\"error\":{:.6}}}}}",
                    e.t_us,
                    e.session,
                    e.a >> 32,
                    e.a & 0xFFFF_FFFF,
                    f64::from_bits(e.b)
                ),
                _ => format!(
                    "{{\"name\":\"{name}\",\"ph\":\"i\",\"ts\":{},\"pid\":{pid},\"tid\":{},\
                     \"s\":\"t\",\"args\":{{\"a\":{},\"b\":{}}}}}",
                    e.t_us, e.session, e.a, e.b
                ),
            };
            push(&mut s, item);
        }
    }
    s.push_str("]}");
    s
}

fn escape_json(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Human-readable per-request rollup of a trace (one row per session).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RequestSummary {
    /// Request id.
    pub session: u64,
    /// Queue wait between submit and admit, microseconds.
    pub queued_us: u64,
    /// Chunked-prefill slices executed.
    pub prefill_chunks: u64,
    /// Times an in-flight prefill was preempted.
    pub preemptions: u64,
    /// Decode ticks that advanced this session (sampled count).
    pub ticks: u64,
    /// Largest decode batch this session rode in.
    pub max_batch: u64,
    /// Recovery snapshots published.
    pub snapshots: u64,
    /// Terminal outcome (`done`/`expired`/`overloaded`/`open`).
    pub outcome: &'static str,
}

impl std::fmt::Display for RequestSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "trace request id={} queued_us={} prefill_chunks={} preemptions={} ticks={} \
             max_batch={} snapshots={} outcome={}",
            self.session,
            self.queued_us,
            self.prefill_chunks,
            self.preemptions,
            self.ticks,
            self.max_batch,
            self.snapshots,
            self.outcome
        )
    }
}

/// Fold a flat event stream into per-request summaries, ordered by
/// session id. Worker-scoped events (`session == 0` telemetry) are
/// ignored; `queued_us` comes from the `Admit` payload so sampling
/// never skews it.
pub fn request_summaries(events: &[TraceEvent]) -> Vec<RequestSummary> {
    let mut by_session: std::collections::BTreeMap<u64, RequestSummary> =
        std::collections::BTreeMap::new();
    for e in events {
        if matches!(
            e.kind,
            EventKind::CacheTelemetry
                | EventKind::ProbeError
                | EventKind::PageIn
                | EventKind::PageOut
        ) {
            continue;
        }
        if e.session == 0 && e.kind == EventKind::DecodeTick {
            continue; // worker-scoped tick span
        }
        let s = by_session.entry(e.session).or_insert_with(|| RequestSummary {
            session: e.session,
            outcome: "open",
            ..Default::default()
        });
        match e.kind {
            EventKind::Admit => s.queued_us = e.a,
            EventKind::PrefillChunk => s.prefill_chunks += 1,
            EventKind::Preempt => s.preemptions += 1,
            EventKind::DecodeTick => {
                s.ticks += 1;
                s.max_batch = s.max_batch.max(e.b);
            }
            EventKind::Snapshot => s.snapshots += 1,
            EventKind::Done => s.outcome = "done",
            EventKind::Expired => s.outcome = "expired",
            EventKind::Overloaded => s.outcome = "overloaded",
            _ => {}
        }
    }
    by_session.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_records_and_decodes_in_order() {
        let r = FlightRecorder::new(64, 1);
        r.record(EventKind::Submit, 7, 12, 4);
        r.record(EventKind::Admit, 7, 55, 12);
        r.record(EventKind::Done, 7, 1000, 4);
        let ev = r.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, EventKind::Submit);
        assert_eq!(ev[0].session, 7);
        assert_eq!(ev[0].a, 12);
        assert_eq!(ev[2].kind, EventKind::Done);
        assert_eq!(r.recorded(), 3);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let r = FlightRecorder::new(16, 1);
        for i in 0..40u64 {
            r.record(EventKind::DecodeTick, 1, i, 1);
        }
        let ev = r.events();
        assert_eq!(ev.len(), 16);
        // The newest 16 events survive the wrap.
        assert_eq!(ev.first().unwrap().a, 24);
        assert_eq!(ev.last().unwrap().a, 39);
        assert_eq!(r.dropped(), 24);
    }

    #[test]
    fn sampling_rate_is_clamped_and_applied() {
        let r = FlightRecorder::new(16, 0);
        assert_eq!(r.sample_every(), 1);
        assert!(r.tick_sampled(0) && r.tick_sampled(1));
        let r = FlightRecorder::new(16, 4);
        assert!(r.tick_sampled(0) && r.tick_sampled(4));
        assert!(!r.tick_sampled(1) && !r.tick_sampled(3));
    }

    #[test]
    fn concurrent_writers_never_corrupt_the_ring() {
        let r = std::sync::Arc::new(FlightRecorder::new(128, 1));
        let mut joins = Vec::new();
        for t in 0..4u64 {
            let r2 = std::sync::Arc::clone(&r);
            joins.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    r2.record(EventKind::DecodeTick, t + 1, i, 1);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(r.recorded(), 2000);
        let ev = r.events();
        assert_eq!(ev.len(), 128);
        for e in &ev {
            assert!(e.session >= 1 && e.session <= 4);
            assert!(e.a < 500);
        }
    }

    #[test]
    fn chrome_trace_emits_tracks_spans_and_counters() {
        let r = FlightRecorder::new(64, 1);
        r.record(EventKind::Submit, 3, 8, 2);
        r.record(EventKind::Admit, 3, 100, 8);
        r.record(EventKind::PrefillChunk, 3, 5_000, 8);
        r.record(EventKind::DecodeTick, 3, 2_000, 1);
        r.record(EventKind::CacheTelemetry, 0, 4096, (5u64 << 32) | 9);
        r.record(EventKind::ProbeError, 3, (1u64 << 32) | 2, 0.25f64.to_bits());
        r.record(EventKind::Done, 3, 77, 2);
        let json = chrome_trace(&[("worker0".to_string(), r.events())]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        for needle in [
            "\"process_name\"",
            "\"worker0\"",
            "\"submit\"",
            "\"admit\"",
            "\"prefill_chunk\"",
            "\"decode_tick\"",
            "\"ph\":\"X\"",
            "\"ph\":\"C\"",
            "\"cache_bytes\":4096",
            "\"cache_clusters\":5",
            "\"cache_reservoir\":9",
            "\"layer\":1,\"head\":2,\"error\":0.25",
            "\"done\"",
            "\"tid\":3",
        ] {
            assert!(json.contains(needle), "missing {needle} in {json}");
        }
        // Balanced braces — cheap structural sanity without a parser.
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn chrome_trace_escapes_track_labels() {
        let json = chrome_trace(&[("w\"0\\\n".to_string(), Vec::new())]);
        assert!(json.contains("w\\\"0\\\\\\n"));
    }

    #[test]
    fn summaries_fold_lifecycle_per_session() {
        let r = FlightRecorder::new(128, 1);
        r.record(EventKind::Submit, 1, 8, 4);
        r.record(EventKind::Admit, 1, 250, 8);
        r.record(EventKind::PrefillChunk, 1, 1_000, 4);
        r.record(EventKind::PrefillChunk, 1, 1_000, 4);
        r.record(EventKind::Preempt, 1, 4, 8);
        r.record(EventKind::DecodeTick, 1, 900, 3);
        r.record(EventKind::DecodeTick, 1, 900, 2);
        r.record(EventKind::Snapshot, 1, 2, 2);
        r.record(EventKind::Done, 1, 5_000, 4);
        r.record(EventKind::Submit, 2, 8, 4);
        r.record(EventKind::Expired, 2, 0, 0);
        r.record(EventKind::CacheTelemetry, 0, 64, 0);
        let rows = request_summaries(&r.events());
        assert_eq!(rows.len(), 2);
        let one = &rows[0];
        assert_eq!(one.session, 1);
        assert_eq!(one.queued_us, 250);
        assert_eq!(one.prefill_chunks, 2);
        assert_eq!(one.preemptions, 1);
        assert_eq!(one.ticks, 2);
        assert_eq!(one.max_batch, 3);
        assert_eq!(one.snapshots, 1);
        assert_eq!(one.outcome, "done");
        assert_eq!(rows[1].outcome, "expired");
        let line = format!("{one}");
        assert!(line.contains("queued_us=250"));
        assert!(line.contains("outcome=done"));
    }
}
