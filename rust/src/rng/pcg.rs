//! PCG-XSL-RR 128/64 ("pcg64") — the main generator.
//!
//! 128-bit LCG state with an xor-shift-low + random-rotate output
//! permutation. Equivalent to the `pcg64` member of O'Neill's PCG family.

use super::{Rng, SplitMix64};

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG-XSL-RR 128/64 state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    /// Stream selector (must be odd); distinct increments give
    /// statistically independent streams for the same seed.
    inc: u128,
}

impl Pcg64 {
    /// Construct from full 128-bit state and stream.
    pub fn new(seed: u128, stream: u128) -> Self {
        let inc = (stream << 1) | 1;
        let mut pcg = Self { state: 0, inc };
        pcg.state = pcg.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        pcg.state = pcg.state.wrapping_add(seed);
        pcg.state = pcg.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        pcg
    }

    /// Expand a 64-bit seed into full state via SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let a = sm.next_u64() as u128;
        let b = sm.next_u64() as u128;
        let c = sm.next_u64() as u128;
        let d = sm.next_u64() as u128;
        Self::new((a << 64) | b, (c << 64) | d)
    }

    /// Raw `(state, inc)` pair for exact serialization. Restore with
    /// [`Self::from_state_parts`] — NOT [`Self::new`], whose seeding
    /// mix steps would land on a different point of the stream.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Rebuild from a [`Self::state_parts`] capture; the restored
    /// generator continues the exact output stream of the original.
    pub fn from_state_parts(state: u128, inc: u128) -> Self {
        Self { state, inc }
    }

    /// Derive a child RNG for a named subsystem: deterministic but
    /// decorrelated from the parent stream. Used to give each layer /
    /// head / policy its own stream from one experiment seed.
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ SplitMix64::mix(tag);
        let b = self.next_u64() ^ SplitMix64::mix(tag.wrapping_add(1));
        let c = self.next_u64();
        let d = self.next_u64();
        Pcg64::new(((a as u128) << 64) | b as u128, ((c as u128) << 64) | d as u128)
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        // XSL-RR output function.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(99, 1);
        let mut b = Pcg64::new(99, 2);
        let equal = (0..128).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(equal < 2);
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Pcg64::seed_from_u64(5);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(0); // same tag, later parent state -> different
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn state_parts_restore_continues_stream() {
        let mut r = Pcg64::seed_from_u64(42);
        for _ in 0..17 {
            r.next_u64();
        }
        let (state, inc) = r.state_parts();
        let mut restored = Pcg64::from_state_parts(state, inc);
        for _ in 0..64 {
            assert_eq!(restored.next_u64(), r.next_u64());
        }
    }

    #[test]
    fn bit_balance() {
        let mut r = Pcg64::seed_from_u64(77);
        let mut ones = 0u64;
        let n = 10_000;
        for _ in 0..n {
            ones += r.next_u64().count_ones() as u64;
        }
        let expect = n * 32;
        let dev = (ones as i64 - expect as i64).abs();
        assert!(dev < 4_000, "ones={ones} expect={expect}");
    }
}
