//! Deterministic pseudo-random number generation.
//!
//! The sandbox has no `rand` crate, so SubGen carries its own small RNG
//! stack: [`SplitMix64`] for seeding / stateless hashing and [`Pcg64`]
//! (PCG-XSL-RR 128/64) as the workhorse generator. Everything in the
//! repository that needs randomness threads one of these through
//! explicitly — there is no global RNG — so every experiment is exactly
//! reproducible from its seed.

mod pcg;
mod splitmix;

pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// Uniform pseudo-random source. Implemented by all RNGs in this crate.
pub trait Rng {
    /// Next 64 uniformly random bits.
    fn next_u64(&mut self) -> u64;

    /// Next 32 uniformly random bits.
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn f64(&mut self) -> f64 {
        // Take the top 53 bits; multiply by 2^-53.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of precision.
    #[inline]
    fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            // Rejection zone to remove modulo bias.
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` index in `[0, bound)`.
    #[inline]
    fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli trial with success probability `p` (clamped to [0,1]).
    #[inline]
    fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal sample (Box–Muller, no caching: simple and
    /// branch-predictable; callers needing bulk gaussians use
    /// [`fill_gaussian`]).
    fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f64::consts::TAU * u2).cos();
            }
        }
    }

    /// Gaussian with the given mean and standard deviation, as f32.
    #[inline]
    fn gaussian32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized, non-negative) weight vector.
    /// Returns `None` when all weights are zero/empty.
    fn categorical(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: fall back to the last positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// Fill a slice with i.i.d. gaussian samples (mean 0, given std).
pub fn fill_gaussian<R: Rng>(rng: &mut R, out: &mut [f32], std: f32) {
    for x in out.iter_mut() {
        *x = rng.gaussian32(0.0, std);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_is_deterministic() {
        let mut a = Pcg64::seed_from_u64(7);
        let mut b = Pcg64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_streams_differ_by_seed() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::seed_from_u64(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 5.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed_from_u64(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn categorical_zero_weights() {
        let mut r = Pcg64::seed_from_u64(1);
        assert_eq!(r.categorical(&[]), None);
        assert_eq!(r.categorical(&[0.0, 0.0]), None);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
