//! SplitMix64 — tiny, fast, full-period 64-bit generator.
//!
//! Used for seed expansion (turning one u64 seed into the 128+ bits of
//! state other generators need) and as a stateless integer mixer.

use super::Rng;

/// SplitMix64 state (Steele, Lea, Flood; JDK 8 `SplittableRandom`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// One mixing step as a pure function (stateless hash of `x`).
    #[inline]
    pub fn mix(x: u64) -> u64 {
        let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // Reference values for seed 1234567 from the canonical C impl.
        let mut r = SplitMix64::new(1234567);
        let first = r.next_u64();
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(first, r2.next_u64());
        // Mixing is not the identity and changes with input.
        assert_ne!(SplitMix64::mix(0), 0);
        assert_ne!(SplitMix64::mix(1), SplitMix64::mix(2));
    }

    #[test]
    fn no_short_cycle() {
        let mut r = SplitMix64::new(42);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..10_000 {
            assert!(seen.insert(r.next_u64()));
        }
    }
}
