//! L3 perf probe: per-step assemble_into cost for exact policy at large
//! C, plus one host-executor decode step — the two serving hot-path
//! costs CI watches on every PR.
fn main() {
    use subgen::model::{HostExecutor, ModelSpec, SequenceCaches};
    let spec = ModelSpec {
        vocab: 16,
        d_model: 64,
        n_heads: 4,
        n_layers: 2,
        d_head: 16,
        prefill_t: 512,
        cache_variants: vec![640, 384, 256, 128],
        decode_batch: 8,
        train_accuracy: -1.0,
    };
    let mut caches = SequenceCaches::new(&spec, "exact", usize::MAX / 4, 0.5, 1).unwrap();
    let x = vec![0.1f32; 2 * 4 * 16];
    for _ in 0..100 {
        caches.update(&x, &x, &x);
    }
    let mut flat = caches.assemble(640).unwrap();
    let t0 = std::time::Instant::now();
    let iters = 500usize;
    for _ in 0..iters {
        caches.update(&x, &x, &x);
        caches.assemble_into(&mut flat).unwrap();
    }
    println!(
        "exact assemble_into: {:.1} µs/step",
        t0.elapsed().as_micros() as f64 / iters as f64
    );

    // One decode step through the pure-rust transformer over the same
    // packed buffers (cache state from the loop above).
    let exec = HostExecutor::new(spec, 1).unwrap();
    let t1 = std::time::Instant::now();
    let iters = 200usize;
    for j in 0..iters {
        let step = exec.decode((j % 16) as i32, 600 + j, &flat).unwrap();
        assert!(step.logits.iter().all(|v| v.is_finite()));
    }
    println!(
        "host decode step   : {:.1} µs/step",
        t1.elapsed().as_micros() as f64 / iters as f64
    );
}
