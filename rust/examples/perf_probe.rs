//! L3 perf probe: per-step assemble_into cost for exact policy at large C.
fn main() {
    use subgen::model::{ModelSpec, SequenceCaches};
    let spec = ModelSpec {
        vocab: 16, d_model: 64, n_heads: 4, n_layers: 2, d_head: 16,
        prefill_t: 512, cache_variants: vec![640, 384, 256, 128],
        decode_batch: 8, train_accuracy: -1.0,
    };
    let mut caches = SequenceCaches::new(&spec, "exact", usize::MAX/4, 0.5, 1).unwrap();
    let x = vec![0.1f32; 2*4*16];
    for _ in 0..100 { caches.update(&x, &x, &x); }
    let mut flat = caches.assemble(640).unwrap();
    let t0 = std::time::Instant::now();
    let iters = 500;
    for _ in 0..iters {
        caches.update(&x, &x, &x);
        caches.assemble_into(&mut flat).unwrap();
    }
    println!("exact assemble_into: {:.1} µs/step", t0.elapsed().as_micros() as f64 / iters as f64);
}
