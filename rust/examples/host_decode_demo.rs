//! Host-executor decode demo: a real autoregressive decode loop under
//! every KV-cache policy, with no PJRT artifacts — the end-to-end form
//! of the paper's Θ(n)-vs-o(n) claim, measured instead of asserted.
//!
//!     cargo run --release --example host_decode_demo -- --tokens 256
//!
//! Two sections:
//!
//! 1. **decode loop** — prefill a prompt, then decode `--tokens` tokens
//!    per policy through the pure-rust transformer, reporting the
//!    retained cache footprint and ns/token (decode + cache update +
//!    flat-buffer reassembly, i.e. the whole serving step). One
//!    `footprint policy=...` line per policy is emitted for CI to grep.
//!    `--batch B` decodes B parallel branches sharing one assembled
//!    cache through one `decode_batch` call per step — the grouped
//!    shared-context path, per-branch reserved slots included; every
//!    branch must produce the same tokens, which the demo asserts, and
//!    ns/token is per generated token across the batch.
//! 2. **scaling** — per-token decode cost at context length
//!    n ∈ `--points` (default 1k/10k/100k): caches are pre-filled to n
//!    and a handful of decode steps are timed, showing exact growing
//!    with n while the compressed policies stay flat.

use anyhow::Result;
use std::time::Instant;
use subgen::bench::{fmt_bytes, Table};
use subgen::cli::Args;
use subgen::kvcache::POLICY_NAMES;
use subgen::model::{DecodeStep, HostExecutor, ModelSpec, SequenceCaches};
use subgen::rng::{fill_gaussian, Pcg64};
use subgen::tensor::argmax;

/// Timed decode steps per scaling operating point (plus 2 warmup).
const SCALING_STEPS: usize = 12;

fn main() -> Result<()> {
    let args = Args::from_env("host-executor decode loop: footprint + ns/token per policy")
        .describe("tokens", Some("512"), "tokens to decode per policy (section 1)")
        .describe("batch", Some("1"), "sequences decoded per batched step (section 1)")
        .describe("prompt", Some("32"), "prompt length (section 1)")
        .describe("budget", Some("192"), "per-head budget for compressed policies")
        .describe("delta", Some("4.0"), "subgen cluster threshold δ")
        .describe("points", Some("1000,10000,100000"), "scaling context lengths (section 2)")
        .describe("seed", Some("7"), "rng seed");
    args.exit_on_help();
    let tokens = args.usize_or("tokens", 512).max(1);
    let batch = args.usize_or("batch", 1).max(1);
    let prompt_len = args.usize_or("prompt", 32).max(1);
    let budget = args.usize_or("budget", 192);
    let delta = args.f32_or("delta", 4.0);
    let seed = args.u64_or("seed", 7);
    let points: Vec<usize> = args
        .get_or("points", "1000,10000,100000")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse().expect("--points must be comma-separated integers"))
        .collect();

    // Cache capacity variants sized so both sections always fit.
    let max_n = points.iter().copied().max().unwrap_or(0);
    let cap = max_n.max(prompt_len + tokens + 2) + 66;
    let mut variants = vec![cap];
    for c in [4096usize, 1024, 320] {
        if c < cap {
            variants.push(c);
        }
    }
    let spec = ModelSpec {
        vocab: 16,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_head: 16,
        prefill_t: prompt_len.max(64),
        cache_variants: variants,
        decode_batch: 0,
        train_accuracy: -1.0,
    };
    let exec = HostExecutor::new(spec.clone(), seed)?;
    println!(
        "host executor: {} layers × {} heads, d_head {}, vocab {} (weights from seed {seed})\n",
        spec.n_layers, spec.n_heads, spec.d_head, spec.vocab
    );

    // ── Section 1: real decode loop per policy ──
    println!(
        "== decode loop: {tokens} tokens × batch {batch} per policy (budget {budget}/head) ==\n"
    );
    let mut table = Table::new(&["policy", "cache bytes", "ns/token", "tok/s"]);
    for &policy in &POLICY_NAMES {
        let (bytes, ns) =
            decode_loop(&exec, &spec, policy, prompt_len, tokens, batch, budget, delta, seed)?;
        println!(
            "footprint policy={policy} tokens={tokens} batch={batch} cache_bytes={bytes} \
             ns_per_token={ns:.0}"
        );
        table.row(&[
            policy.to_string(),
            fmt_bytes(bytes),
            format!("{ns:.0}"),
            format!("{:.0}", 1e9 / ns),
        ]);
    }
    println!();
    table.print();

    // ── Section 2: decode cost vs context length ──
    if !points.is_empty() {
        println!("\n== scaling: decode ns/token at context length n ==\n");
        let mut t2 = Table::new(&["n", "policy", "cache bytes", "ns/token", "vs exact bytes"]);
        for &n in &points {
            let mut exact_bytes = 0usize;
            for &policy in &POLICY_NAMES {
                let (bytes, ns) = scaling_point(&exec, &spec, policy, n, budget, delta, seed)?;
                if policy == "exact" {
                    exact_bytes = bytes;
                }
                println!("scaling policy={policy} n={n} cache_bytes={bytes} ns_per_token={ns:.0}");
                let ratio = if exact_bytes > 0 {
                    format!("{:.1}x smaller", exact_bytes as f64 / bytes.max(1) as f64)
                } else {
                    "-".into()
                };
                t2.row(&[
                    n.to_string(),
                    policy.to_string(),
                    fmt_bytes(bytes),
                    format!("{ns:.0}"),
                    ratio,
                ]);
            }
        }
        println!();
        t2.print();
        println!("\n(exact ns/token grows with n; compressed policies stay flat — sublinearity)");
    }
    Ok(())
}

/// Section 1 body: prefill, then a full greedy decode loop (decode +
/// cache update + flat reassembly per step). With `batch > 1` the
/// decode runs as `batch` parallel branches **sharing one assembled
/// `FlatCaches`** through a single `decode_batch` call per step — the
/// shared-context form that drives the grouped nq > 1 attention sweep
/// with per-branch reserved slots, not just the batched matvecs. The
/// branches are identical by construction, so their outputs must agree
/// bit-for-bit (asserted). Returns (cache bytes at completion, mean ns
/// per generated token across the batch).
fn decode_loop(
    exec: &HostExecutor,
    spec: &ModelSpec,
    policy: &str,
    prompt_len: usize,
    tokens: usize,
    batch: usize,
    budget: usize,
    delta: f32,
    seed: u64,
) -> Result<(usize, f64)> {
    let b = if policy == "exact" { usize::MAX / 4 } else { budget };
    let mut caches = SequenceCaches::new(spec, policy, b, delta, seed ^ 0xC0FFEE)?;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| (i % spec.vocab) as i32).collect();
    let pre = exec.prefill(&prompt)?;
    for p in 0..prompt.len() {
        caches.update(
            &exec.position_slice(&pre.qs, p),
            &exec.position_slice(&pre.ks, p),
            &exec.position_slice(&pre.vs, p),
        );
    }
    let v = spec.vocab;
    let mut next = argmax(&pre.logits[(prompt_len - 1) * v..prompt_len * v]) as i32;
    let c = spec.pick_cache_variant(caches.max_slots() + 1);
    let mut flat = caches.assemble(c)?;
    let t0 = Instant::now();
    for j in 0..tokens {
        let steps: Vec<DecodeStep<'_>> = (0..batch)
            .map(|_| DecodeStep { token: next, pos: prompt_len + j, flat: &flat })
            .collect();
        let outs = exec.decode_batch(&steps)?;
        drop(steps);
        for out in &outs[1..] {
            assert_eq!(out.logits, outs[0].logits, "{policy}: branches diverged at step {j}");
        }
        let step = &outs[0];
        caches.update(&step.q, &step.k, &step.v);
        next = argmax(&step.logits) as i32;
        caches.reassemble(spec, &mut flat)?;
    }
    let ns = t0.elapsed().as_nanos() as f64 / (tokens * batch) as f64;
    Ok((caches.memory_bytes(), ns))
}

/// Section 2 body: pre-fill caches with `n` synthetic tokens, then time
/// a handful of pure decode steps at that context length.
fn scaling_point(
    exec: &HostExecutor,
    spec: &ModelSpec,
    policy: &str,
    n: usize,
    budget: usize,
    delta: f32,
    seed: u64,
) -> Result<(usize, f64)> {
    let b = if policy == "exact" { usize::MAX / 4 } else { budget };
    let mut caches = SequenceCaches::new(spec, policy, b, delta, seed ^ n as u64)?;
    let lh_dh = spec.n_layers * spec.n_heads * spec.d_head;
    let mut rng = Pcg64::seed_from_u64(seed ^ 0x5CA1E ^ n as u64);
    let (mut q, mut k, mut v) = (vec![0.0f32; lh_dh], vec![0.0f32; lh_dh], vec![0.0f32; lh_dh]);
    for _ in 0..n {
        fill_gaussian(&mut rng, &mut q, 0.3);
        fill_gaussian(&mut rng, &mut k, 0.3);
        fill_gaussian(&mut rng, &mut v, 1.0);
        caches.update(&q, &k, &v);
    }
    let c = spec.pick_cache_variant(caches.max_slots() + 1);
    let flat = caches.assemble(c)?;
    for w in 0..2 {
        let _ = exec.decode((w % spec.vocab) as i32, n + w, &flat)?;
    }
    let t0 = Instant::now();
    for j in 0..SCALING_STEPS {
        let step = exec.decode(((j + 1) % spec.vocab) as i32, n + j, &flat)?;
        assert!(step.logits.iter().all(|x| x.is_finite()), "{policy} n={n}: non-finite logits");
    }
    let ns = t0.elapsed().as_nanos() as f64 / SCALING_STEPS as f64;
    Ok((caches.memory_bytes(), ns))
}
