//! Quickstart: the SubGen streaming-attention data structure on its own
//! (no model, no artifacts) — Algorithm 1 against exact attention —
//! followed by a short end-to-end decode through the serving engine
//! over the pure-rust host executor.
//!
//!     cargo run --release --example quickstart
//!     cargo run --release --example quickstart -- --executor none   # sketch only
//!
//! Streams an (m, δ)-clusterable sequence of (q, k, v) tokens through
//! [`subgen::subgen::SubGenAttention`], then compares the estimator's
//! output, memory and the paper's error bound (Eq. 3) to the exact
//! softmax attention kept alongside. With `--executor host` (the
//! default) it finishes by serving a few requests per cache policy
//! through `Engine` + `HostExecutor` — a real transformer decode loop,
//! still artifact-free.

use subgen::attention::{error_bound_rhs, exact_attention};
use subgen::bench::fmt_bytes;
use subgen::cli::Args;
use subgen::coordinator::{Engine, EngineConfig, HostExecutor, Request, RequestClass};
use subgen::kvcache::bytes_per_slot;
use subgen::subgen::{SubGenAttention, SubGenConfig};
use subgen::tensor::Tensor;
use subgen::workload::{ClusterableStream, TokenStream};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env("SubGen quickstart: sketch accuracy + host-executor decode")
        .describe("executor", Some("host"), "decode demo executor (host|none)");
    args.exit_on_help();
    let dim = 32;
    let n = 32_768;
    let m = 12; // planted clusters
    println!("SubGen quickstart: n={n} stream, {m} planted key clusters, d={dim}\n");

    // Theorem-1 style parameters: eps=0.5, query norm r=1, delta=0.5.
    let cfg = SubGenConfig::for_error(dim, 0.5, 0.5, 1.0, n);
    println!("config: delta={} t={} s={}", cfg.delta, cfg.t, cfg.s);

    let mut stream = ClusterableStream::new(dim, m, 0.05, 1.0, 42);
    let mut sketch = SubGenAttention::new(cfg, 7);

    // Exact reference (the O(n·d) cache SubGen replaces).
    let mut keys = Tensor::zeros(0, dim);
    let mut values = Tensor::zeros(0, dim);
    let mut last_q = vec![0.0f32; dim];

    for _ in 0..n {
        let (q, k, v) = stream.next_triplet();
        sketch.update(&k, &v);
        keys.push_row(&k);
        values.push_row(&v);
        last_q = q;
    }

    let est = sketch.query(&last_q);
    let exact = exact_attention(&last_q, &keys, &values);
    let err: f32 = est.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
    let bound = error_bound_rhs(0.5, &last_q, &keys, &values);

    println!("\nclusters found : {} (planted {m})", sketch.num_clusters());
    println!("‖z − Attn‖₂    : {err:.4}");
    println!("ε·‖softmax‖·‖V‖op (Eq. 3 bound): {bound:.4}");
    println!("bound satisfied: {}", err <= bound);

    let exact_bytes = n * bytes_per_slot(dim);
    println!("\nmemory — exact cache : {}", fmt_bytes(exact_bytes));
    println!("memory — subgen      : {}", fmt_bytes(sketch.memory_bytes()));
    println!(
        "compression          : {:.1}x",
        exact_bytes as f64 / sketch.memory_bytes() as f64
    );

    // Partition-function accuracy (the paper's core estimator).
    let tau = sketch.partition_estimate(&last_q);
    let exact_tau = subgen::attention::exact_log_partition(&last_q, &keys).exp() as f64;
    println!(
        "\npartition fn   : est {tau:.3e} vs exact {exact_tau:.3e} (rel {:.3}%)",
        100.0 * (tau - exact_tau).abs() / exact_tau
    );

    match args.get_or("executor", "host").as_str() {
        "host" => host_decode_demo()?,
        "none" => {}
        other => anyhow::bail!("unknown executor {other:?} (host|none)"),
    }
    Ok(())
}

/// A taste of the serving stack: the same estimator running inside a
/// real (pure-rust, artifact-free) transformer decode loop, one request
/// per cache policy.
fn host_decode_demo() -> anyhow::Result<()> {
    println!("\n== engine decode over the host executor (no artifacts) ==\n");
    let exec = HostExecutor::small(42);
    for policy in subgen::kvcache::POLICY_NAMES {
        let mut engine = Engine::new(&exec, EngineConfig::default());
        engine.submit(Request {
            id: 0,
            session_id: None,
            prompt: vec![1, 2, 3, 4, 5],
            max_new: 8,
            policy: policy.to_string(),
            budget: 16,
            delta: 0.5,
            deadline: None,
            class: RequestClass::Interactive,
        });
        engine.run_to_completion()?;
        let resp = engine.take_responses().pop().expect("one response");
        println!(
            "policy {policy:<8}: {} tokens, cache {}",
            resp.tokens.len(),
            fmt_bytes(resp.cache_bytes)
        );
    }
    Ok(())
}
