//! Quickstart: the SubGen streaming-attention data structure on its own
//! (no model, no artifacts) — Algorithm 1 against exact attention.
//!
//!     cargo run --release --example quickstart
//!
//! Streams an (m, δ)-clusterable sequence of (q, k, v) tokens through
//! [`subgen::subgen::SubGenAttention`], then compares the estimator's
//! output, memory and the paper's error bound (Eq. 3) to the exact
//! softmax attention kept alongside.

use subgen::attention::{error_bound_rhs, exact_attention};
use subgen::bench::fmt_bytes;
use subgen::kvcache::bytes_per_slot;
use subgen::subgen::{SubGenAttention, SubGenConfig};
use subgen::tensor::Tensor;
use subgen::workload::{ClusterableStream, TokenStream};

fn main() -> anyhow::Result<()> {
    let dim = 32;
    let n = 32_768;
    let m = 12; // planted clusters
    println!("SubGen quickstart: n={n} stream, {m} planted key clusters, d={dim}\n");

    // Theorem-1 style parameters: eps=0.5, query norm r=1, delta=0.5.
    let cfg = SubGenConfig::for_error(dim, 0.5, 0.5, 1.0, n);
    println!("config: delta={} t={} s={}", cfg.delta, cfg.t, cfg.s);

    let mut stream = ClusterableStream::new(dim, m, 0.05, 1.0, 42);
    let mut sketch = SubGenAttention::new(cfg, 7);

    // Exact reference (the O(n·d) cache SubGen replaces).
    let mut keys = Tensor::zeros(0, dim);
    let mut values = Tensor::zeros(0, dim);
    let mut last_q = vec![0.0f32; dim];

    for _ in 0..n {
        let (q, k, v) = stream.next_triplet();
        sketch.update(&k, &v);
        keys.push_row(&k);
        values.push_row(&v);
        last_q = q;
    }

    let est = sketch.query(&last_q);
    let exact = exact_attention(&last_q, &keys, &values);
    let err: f32 =
        est.iter().zip(&exact).map(|(a, b)| (a - b) * (a - b)).sum::<f32>().sqrt();
    let bound = error_bound_rhs(0.5, &last_q, &keys, &values);

    println!("\nclusters found : {} (planted {m})", sketch.num_clusters());
    println!("‖z − Attn‖₂    : {err:.4}");
    println!("ε·‖softmax‖·‖V‖op (Eq. 3 bound): {bound:.4}");
    println!("bound satisfied: {}", err <= bound);

    let exact_bytes = n * bytes_per_slot(dim);
    println!("\nmemory — exact cache : {}", fmt_bytes(exact_bytes));
    println!("memory — subgen      : {}", fmt_bytes(sketch.memory_bytes()));
    println!(
        "compression          : {:.1}x",
        exact_bytes as f64 / sketch.memory_bytes() as f64
    );

    // Partition-function accuracy (the paper's core estimator).
    let tau = sketch.partition_estimate(&last_q);
    let exact_tau = subgen::attention::exact_log_partition(&last_q, &keys).exp() as f64;
    println!(
        "\npartition fn   : est {tau:.3e} vs exact {exact_tau:.3e} (rel {:.3}%)",
        100.0 * (tau - exact_tau).abs() / exact_tau
    );
    Ok(())
}
