//! Table-1-shaped retrieval accuracy run, end to end on the pure-rust
//! stack: train (or load) a host transformer, then decode held-out
//! line-retrieval documents through the serving engine under **all five
//! cache policies at matched per-head budgets** and print the accuracy
//! table the paper's headline claim is about.
//!
//!     cargo run --release --example eval_retrieval -- --steps 1500
//!     cargo run --release --example eval_retrieval -- --checkpoint subgen_host.ck
//!
//! The sweep covers **dtype × policy × budget**: each KV encoding
//! (`--kv-dtypes`, default `f32,f16,int8`) re-runs the whole table so
//! quantized accuracy can be read off against the f32 reference. One
//! `accuracy policy=<p> dtype=<d> budget=<b> …` line per table cell is
//! emitted for CI/grep consumption, and the whole sweep lands in
//! `BENCH_accuracy.json` (trend tracking; no `*_ns` keys, so the perf
//! gate ignores it).

use anyhow::Result;
use std::path::Path;
use subgen::bench::Table;
use subgen::cli::Args;
use subgen::io::Checkpoint;
use subgen::kvcache::POLICY_NAMES;
use subgen::model::{HostExecutor, ModelSpec};
use subgen::train::{accuracy_json_encoded, evaluate_policies, EvalConfig, TrainConfig, Trainer};
use subgen::workload::seq_len_for_lines;

fn main() -> Result<()> {
    let args = Args::from_env("per-policy retrieval accuracy at matched budgets")
        .describe("checkpoint", None, "trained checkpoint to evaluate (skips training)")
        .describe("steps", Some("5000"), "max optimizer steps when training here")
        .describe("batch", Some("16"), "documents per optimizer step")
        .describe("lr", Some("0.002"), "peak learning rate")
        .describe("lines-min", Some("2"), "min training document lines")
        .describe("lines-max", Some("4"), "max training document lines")
        .describe("lines", Some("4"), "held-out document lines (eval)")
        .describe("questions", Some("50"), "held-out documents per policy")
        .describe("budgets", Some("24,32,48"), "per-head budgets to sweep")
        .describe("kv-dtypes", Some("f32,f16,int8"), "KV encodings to sweep")
        .describe("delta", Some("4.0"), "subgen cluster threshold δ")
        .describe("json", None, "output path (default ../BENCH_accuracy.json)")
        .describe("seed", Some("0"), "rng seed");
    args.exit_on_help();
    let lines = args.usize_or("lines", 4).clamp(1, 100);
    let questions = args.usize_or("questions", 50);
    let delta = args.f32_or("delta", 4.0);
    let seed = args.u64_or("seed", 0);
    let budgets: Vec<usize> = args
        .get_or("budgets", "24,32,48")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().parse().expect("--budgets must be comma-separated integers"))
        .collect();
    let dtypes: Vec<String> = args
        .get_or("kv-dtypes", "f32,f16,int8")
        .split(',')
        .filter(|s| !s.trim().is_empty())
        .map(|s| s.trim().to_string())
        .collect();

    // ── Model: load a checkpoint or train one right here ──
    let ck = match args.get("checkpoint") {
        Some(path) => Checkpoint::load(Path::new(path))?,
        None => {
            let spec = ModelSpec {
                vocab: subgen::workload::VOCAB,
                d_model: 48,
                n_heads: 4,
                n_layers: 2,
                d_head: 12,
                prefill_t: 512,
                cache_variants: vec![640, 384, 256, 128],
                decode_batch: 0,
                train_accuracy: -1.0,
            };
            let cfg = TrainConfig {
                lines_min: args.usize_or("lines-min", 2),
                lines_max: args.usize_or("lines-max", 4).max(lines),
                batch: args.usize_or("batch", 16),
                steps: args.usize_or("steps", 5000),
                lr: args.f32_or("lr", 2e-3),
                seed,
                log: true,
                ..Default::default()
            };
            // Pre-flight before spending the training run: the longest
            // training document must fit the exported spec's prefill.
            anyhow::ensure!(
                seq_len_for_lines(cfg.lines_max) <= spec.prefill_t,
                "--lines {} needs {} tokens, beyond prefill_t {}",
                cfg.lines_max,
                seq_len_for_lines(cfg.lines_max),
                spec.prefill_t
            );
            let mut trainer = Trainer::new(spec, cfg)?;
            let report = trainer.run()?;
            println!(
                "trained: steps={} loss={:.4} held-out accuracy={:.3}\n",
                report.steps, report.final_loss, report.accuracy
            );
            trainer.into_model().to_checkpoint()
        }
    };
    let exec = HostExecutor::from_checkpoint(&ck)?;
    let train_acc = exec.spec().train_accuracy;
    println!(
        "eval: {} lines/doc ({} tokens), {questions} docs/policy, budgets {budgets:?}, \
         train_accuracy={train_acc:.3}\n",
        lines,
        seq_len_for_lines(lines)
    );

    // ── The sweep: every dtype × policy × budget, identical documents ──
    let headers: Vec<String> = std::iter::once("policy".to_string())
        .chain(budgets.iter().map(|b| format!("b={b}")))
        .collect();
    let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&header_refs);
    let mut sweeps = Vec::with_capacity(dtypes.len() * budgets.len());
    for dtype in &dtypes {
        for &budget in &budgets {
            let cfg = EvalConfig {
                questions,
                n_lines: lines,
                budget,
                delta,
                seed: seed ^ 0x5EED_E7A1,
                kv_dtype: dtype.clone(),
            };
            let rows = evaluate_policies(&exec, &POLICY_NAMES, &cfg)?;
            for r in &rows {
                println!(
                    "accuracy policy={} dtype={dtype} budget={budget} lines={lines} \
                     correct={}/{} acc={:.3} cache_bytes={:.0}",
                    r.policy, r.correct, r.total, r.accuracy(), r.mean_cache_bytes
                );
            }
            sweeps.push((dtype.clone(), budget, rows));
        }
    }
    for dtype in &dtypes {
        for (pi, &policy) in POLICY_NAMES.iter().enumerate() {
            let label =
                if dtypes.len() > 1 { format!("{policy}@{dtype}") } else { policy.to_string() };
            let mut cells = vec![label];
            for (d, _, rows) in &sweeps {
                if d == dtype {
                    cells.push(format!("{:.3}", rows[pi].accuracy()));
                }
            }
            table.row(&cells);
        }
    }
    println!();
    table.print();
    println!("\n(exact is the uncompressed reference; compressed rows share each budget)");

    let json = accuracy_json_encoded(&sweeps, lines, questions, delta, train_acc);
    let default_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_accuracy.json");
    let path = args.get_or("json", default_path);
    std::fs::write(&path, json)?;
    println!("\nwrote {path}");
    Ok(())
}
