//! Figure 1 reproduction: clusterability of cached key vs value
//! embeddings, with greedy k-center centers and t-SNE coordinates.
//!
//!     cargo run --release --example clusterability [-- --steps 1024]
//!
//! Paper: t-SNE of Llama-2-7B K/V caches over 1024 timesteps (MT-Bench),
//! layers {0, 7, 15, 23, 31}, k-center with k = 16; keys cluster visibly
//! better than values. Here: the trained retrieval model decoding mixed
//! synthetic prompts; every layer × head; the qualitative plot becomes
//! (a) CSVs of t-SNE coords + center flags under artifacts/fig1/ and
//! (b) a quantitative table — normalized k-center radius of keys vs
//! values (lower = more clusterable), reproducing the paper's claim as a
//! measurable gap.

use anyhow::Result;
use std::path::PathBuf;
use subgen::bench::Table;
use subgen::cli::Args;
use subgen::clustering::{greedy_k_center, ClusterStats};
use subgen::io::CsvWriter;
use subgen::model::{Generator, ModelSpec, SequenceCaches};
use subgen::rng::{Pcg64, Rng};
use subgen::runtime::Runtime;
use subgen::tensor::Tensor;
use subgen::tsne::{tsne, TsneConfig};
use subgen::workload::{lines_for_seq_len_clamped, RetrievalSampler};

fn main() -> Result<()> {
    let args = Args::from_env("Figure 1: key/value clusterability")
        .describe("artifacts", Some("artifacts"), "artifacts directory")
        .describe("steps", Some("1024"), "timesteps of cache to harvest")
        .describe("k", Some("16"), "k-center probe size (paper: 16)")
        .describe("tsne", Some("true"), "also write t-SNE CSVs (slow-ish)")
        .describe("seed", Some("0"), "rng seed");
    args.exit_on_help();
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let steps = args.usize_or("steps", 1024);
    let k = args.usize_or("k", 16);
    let do_tsne = args.get_or("tsne", "true") != "false";
    let seed = args.u64_or("seed", 0);

    let rt = Runtime::load(&artifacts, None)?;
    let spec = ModelSpec::from_manifest(rt.manifest())?;
    let generator = Generator::new(&rt, spec.clone());

    // Harvest K/V embeddings over `steps` timesteps by decoding a mix of
    // retrieval prompts (the MT-Bench analog: varied content).
    println!("harvesting {} timesteps of K/V cache ...", steps);
    let (keys, values) = harvest(&generator, &spec, steps, seed)?;

    // Quantitative Figure 1: clusterability per layer × head.
    let mut table = Table::new(&[
        "layer", "head", "keys radius*", "values radius*", "keys m_eff", "values m_eff", "keys win",
    ]);
    let mut wins = 0usize;
    let mut cells = 0usize;
    for l in 0..spec.n_layers {
        for h in 0..spec.n_heads {
            let ks = &keys[l * spec.n_heads + h];
            let vs = &values[l * spec.n_heads + h];
            let sk = ClusterStats::compute(ks, k);
            let sv = ClusterStats::compute(vs, k);
            let win = sk.normalized_radius < sv.normalized_radius;
            wins += win as usize;
            cells += 1;
            table.row(&[
                l.to_string(),
                h.to_string(),
                format!("{:.3}", sk.normalized_radius),
                format!("{:.3}", sv.normalized_radius),
                sk.effective_m.to_string(),
                sv.effective_m.to_string(),
                if win { "yes".into() } else { "no".into() },
            ]);
        }
    }
    println!();
    table.print();
    println!("\n*k-center covering radius / RMS norm (lower = more clusterable)");
    println!(
        "keys more clusterable than values in {wins}/{cells} (paper: keys win across layers/heads)"
    );

    if do_tsne {
        let dir = artifacts.join("fig1");
        println!("\nwriting t-SNE coordinates to {} ...", dir.display());
        for l in 0..spec.n_layers {
            // One random head per layer, as in the paper.
            let mut rng = Pcg64::seed_from_u64(seed ^ (l as u64) << 8);
            let h = rng.index(spec.n_heads);
            for (tag, data) in
                [("keys", &keys[l * spec.n_heads + h]), ("values", &values[l * spec.n_heads + h])]
            {
                let cfg = TsneConfig { perplexity: 30.0, iters: 250, seed, ..Default::default() };
                let y = tsne(data, &cfg);
                let centers = greedy_k_center(data, k, 0);
                let mut w = CsvWriter::create(
                    &dir.join(format!("l{l}_h{h}_{tag}.csv")),
                    &["x", "y", "is_center"],
                )?;
                let center_set: std::collections::HashSet<usize> =
                    centers.centers.iter().copied().collect();
                for i in 0..y.rows() {
                    w.write_row(&[
                        y.get(i, 0).to_string(),
                        y.get(i, 1).to_string(),
                        (center_set.contains(&i) as u8).to_string(),
                    ])?;
                }
                w.flush()?;
            }
            println!("  layer {l} head {h}: keys + values CSVs written");
        }
    }
    Ok(())
}

/// Decode through mixed prompts, feeding every step's K/V into exact
/// per-head caches, until `steps` timesteps are collected per head.
fn harvest(
    generator: &Generator,
    spec: &ModelSpec,
    steps: usize,
    seed: u64,
) -> Result<(Vec<Tensor>, Vec<Tensor>)> {
    let lh = spec.n_layers * spec.n_heads;
    let mut keys: Vec<Tensor> = (0..lh).map(|_| Tensor::zeros(0, spec.d_head)).collect();
    let mut values: Vec<Tensor> = (0..lh).map(|_| Tensor::zeros(0, spec.d_head)).collect();
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut collected = 0usize;
    let mut round = 0u64;
    while collected < steps {
        // Vary document length for diversity (the MT-Bench analog).
        let lines = 8 + ((round * 13) % 48) as usize;
        let n = subgen::workload::seq_len_for_lines(lines).min(spec.prefill_t);
        let inst = sampler.sample(lines_for_seq_len_clamped(n));
        let (prompt, answer) = inst.tokens();
        let mut caches = SequenceCaches::new(spec, "exact", usize::MAX / 4, 0.5, seed)?;
        let _ = generator.generate(&prompt, answer.len(), &mut caches)?;
        // Extract from the prefill replay: run prefill again for the
        // harvest (cheap at this scale) and slice per (l, h).
        let pre = generator.prefill(&prompt)?;
        let take = prompt.len().min(steps - collected);
        for pos in 0..take {
            let kpos = generator.position_slice(&pre.ks, pos);
            let vpos = generator.position_slice(&pre.vs, pos);
            for l in 0..spec.n_layers {
                for h in 0..spec.n_heads {
                    let at = (l * spec.n_heads + h) * spec.d_head;
                    keys[l * spec.n_heads + h].push_row(&kpos[at..at + spec.d_head]);
                    values[l * spec.n_heads + h].push_row(&vpos[at..at + spec.d_head]);
                }
            }
        }
        collected += take;
        round += 1;
    }
    Ok((keys, values))
}
