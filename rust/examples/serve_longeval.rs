//! Table 1 reproduction: line-retrieval accuracy under matched KV-cache
//! budgets, across context lengths and compression policies.
//!
//!     cargo run --release --example serve_longeval [-- --questions 50]
//!     make artifacts && cargo run --release --example serve_longeval -- --executor artifact
//!
//! Paper (LongEval, longchat-7B): n ∈ {5k, 7k, 9k}, cache reductions
//! {35%, 42%, 50%}, policies Exact / Sink / H2O / SubGen. Scaled to this
//! testbed (DESIGN.md §Substitutions): n ∈ {128, 256, 384} on the
//! from-scratch retrieval model, same reduction schedule, same metric
//! (exact-answer accuracy), cache bytes from real buffer accounting.
//!
//! `--executor host` (the default) runs the whole grid on the pure-rust
//! [`HostExecutor`] — random weights, so accuracy is chance-level, but
//! every cache policy serves a genuine decode loop with no artifacts.
//! `--executor artifact` restores the trained PJRT path (requires
//! `make artifacts` and the real `xla` crate).

use anyhow::Result;
use std::path::PathBuf;
use subgen::bench::{fmt_bytes, Table};
use subgen::cli::Args;
use subgen::coordinator::{Engine, EngineConfig, HostExecutor, Request, RequestClass, StepExecutor};
use subgen::model::{Generator, ModelSpec};
use subgen::rng::Pcg64;
use subgen::runtime::Runtime;
use subgen::workload::{lines_for_seq_len_clamped, RetrievalSampler};

/// Paper's Table-1 cache-reduction schedule per context length (lengths
/// scaled to where the CPU-trained model retrieves reliably; the paper's
/// own exact-policy ceiling also degrades at its longest length).
const REDUCTIONS: [(usize, f64); 3] = [(128, 0.35), (256, 0.42), (384, 0.50)];
const POLICIES: [&str; 4] = ["exact", "sink", "h2o", "subgen"];

fn main() -> Result<()> {
    let args = Args::from_env("Table 1: retrieval accuracy under KV compression")
        .describe("executor", Some("host"), "decode backend (host|artifact)")
        .describe("artifacts", Some("artifacts"), "artifacts directory (artifact executor)")
        .describe("questions", Some("50"), "questions per cell")
        .describe("delta", Some("4.0"), "subgen cluster threshold δ")
        .describe("seed", Some("0"), "rng seed");
    args.exit_on_help();
    let questions = args.usize_or("questions", 50);
    let delta = args.f32_or("delta", 4.0);
    let seed = args.u64_or("seed", 0);

    match args.get_or("executor", "host").as_str() {
        "host" => {
            let exec = HostExecutor::retrieval(seed ^ 0xBEEF);
            println!("executor: host (pure-rust transformer, untrained weights)");
            run_grid(&exec, questions, delta, seed)
        }
        "artifact" => {
            let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
            let rt = Runtime::load(&artifacts, None)?;
            let spec = ModelSpec::from_manifest(rt.manifest())?;
            let generator = Generator::new(&rt, spec);
            println!("executor: artifact (PJRT)");
            run_grid(&generator, questions, delta, seed)
        }
        other => anyhow::bail!("unknown executor {other:?} (host|artifact)"),
    }
}

fn run_grid<E: StepExecutor>(exec: &E, questions: usize, delta: f32, seed: u64) -> Result<()> {
    let spec = exec.spec();
    println!(
        "model: {} layers, {} heads, d_head {}, trained answer-digit acc {:.3}\n",
        spec.n_layers, spec.n_heads, spec.d_head, spec.train_accuracy
    );

    let mut table = Table::new(&[
        "n", "policy", "budget/head", "cache bytes", "reduction", "accuracy",
    ]);

    for &(n, reduction) in &REDUCTIONS {
        // Budget matching: compressed policies get (1-reduction)·n slots
        // per head; exact keeps everything.
        let budget = ((n as f64) * (1.0 - reduction)).round() as usize;
        let mut exact_bytes = 0usize;
        for &policy in &POLICIES {
            let b = if policy == "exact" { usize::MAX / 4 } else { budget };
            let (acc, bytes) = run_cell(exec, n, questions, policy, b, delta, seed)?;
            if policy == "exact" {
                exact_bytes = bytes;
            }
            let red = if exact_bytes > 0 {
                format!("{:.0}% ↓", 100.0 * (1.0 - bytes as f64 / exact_bytes as f64))
            } else {
                "-".into()
            };
            table.row(&[
                n.to_string(),
                policy.to_string(),
                if policy == "exact" { "-".into() } else { budget.to_string() },
                fmt_bytes(bytes),
                red,
                format!("{acc:.2}"),
            ]);
        }
    }
    println!();
    table.print();
    println!("\n(paper Table 1 shape: SubGen > H2O ≥ Sink per length; exact is the ceiling)");
    Ok(())
}

/// One (length, policy) cell: accuracy over `questions` + cache bytes of
/// the last sequence.
fn run_cell<E: StepExecutor>(
    exec: &E,
    n: usize,
    questions: usize,
    policy: &str,
    budget: usize,
    delta: f32,
    seed: u64,
) -> Result<(f64, usize)> {
    let mut engine =
        Engine::new(exec, EngineConfig::builder().max_active(4).prefills_per_tick(2).build());
    // Same question set across policies (same seed).
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed ^ n as u64));
    let mut expected = Vec::new();
    for id in 0..questions {
        let inst = sampler.sample(lines_for_seq_len_clamped(n));
        let (prompt, answer) = inst.tokens();
        expected.push(answer.clone());
        engine.submit(Request {
            id: id as u64,
            session_id: None,
            prompt,
            max_new: 2,
            policy: policy.to_string(),
            budget,
            delta,
            deadline: None,
            class: RequestClass::Interactive,
        });
    }
    engine.run_to_completion()?;
    let responses = engine.take_responses();
    let correct = responses
        .iter()
        .filter(|r| r.tokens == expected[r.id as usize])
        .count();
    let bytes = responses.iter().map(|r| r.cache_bytes).max().unwrap_or(0);
    Ok((correct as f64 / questions as f64, bytes))
}
