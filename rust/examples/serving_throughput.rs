//! End-to-end serving driver (the serving-paper e2e requirement):
//! open-loop Poisson load against the **sharded cluster router** —
//! `--workers` engine threads behind one front door — reporting latency
//! percentiles, per-worker utilization, and aggregate tokens/sec per
//! cache policy.
//!
//!     cargo run --release --example serving_throughput -- --workers 2
//!
//! The headline serving claim of a KV-compression paper is that smaller
//! caches keep decode latency flat as contexts grow *and* let one
//! machine hold more concurrent sequences; the router turns N cores
//! into N continuous-batching engines, so the aggregate tokens/sec
//! scales with workers while per-sequence cache memory stays bounded.
//!
//! Output per policy: one `cluster policy=<p> worker=<i> ...` line per
//! worker (CI greps these) and one `cluster policy=<p> aggregate
//! tokens_per_sec=...` line, plus the latency table.
//!
//! `--executor host` (the default) builds one pure-rust
//! [`subgen::model::HostExecutor`] per worker; `--executor artifact`
//! restores the compiled-executable path (single worker — the PJRT
//! runtime is thread-bound, so it cannot be built from a `Send`
//! factory).

use anyhow::Result;
use std::path::PathBuf;
use subgen::bench::Table;
use subgen::cli::Args;
use subgen::coordinator::{
    EngineConfig, FaultPlan, HostExecutor, Request, RequestClass, StepExecutor,
};
use subgen::model::{FlatCaches, Generator, ModelSpec};
use subgen::rng::Pcg64;
use subgen::runtime::Runtime;
use subgen::server::{
    channel, prometheus_text, serve, ChaosReport, ClusterSnapshot, LoadGen, LoadGenReport, Router,
    RouterConfig, StreamingReport, SubmitError,
};
use subgen::trace::{chrome_trace, request_summaries, FlightRecorder, TraceEvent};
use subgen::workload::{lines_for_seq_len_clamped, RetrievalSampler};

fn main() -> Result<()> {
    let args = Args::from_env("serving throughput under Poisson load (sharded router)")
        .describe("executor", Some("host"), "decode backend (host|artifact)")
        .describe("artifacts", Some("artifacts"), "artifacts directory (artifact executor)")
        .describe("workers", Some("2"), "worker engines behind the router (host executor)")
        .describe("requests", Some("24"), "requests per policy")
        .describe("rate", Some("4.0"), "mean arrival rate (req/s)")
        .describe("n", Some("384"), "prompt length (tokens)")
        .describe("new", Some("8"), "tokens generated per request")
        .describe("budget", Some("192"), "per-head budget for compressed policies")
        .describe("chaos", None, "inject a worker kill and report recovery (kill-one)")
        .describe("mixed", None, "mixed-load run: long batch prefills + interactive decode, \
                   chunked-prefill scheduler vs monolithic")
        .describe("prefill-chunk", Some("32"), "prefill token budget per tick in --mixed")
        .describe("paged", None, "memory-pressure run: unbounded KV pool vs --kv-budget-pct \
                   of the working set, asserting bit-identical tokens")
        .describe("kv-budget-pct", Some("25"), "paged-pool budget as % of the working set \
                   in --paged")
        .describe("kv-dtype", Some("f32"), "KV-cache storage encoding: f32|f16|int8")
        .describe("trace-out", None, "write a merged Chrome trace-event JSON (all policy runs, \
                   one track per worker) to this path and print per-request summaries")
        .describe("seed", Some("0"), "rng seed");
    args.exit_on_help();
    let executor = args.get_or("executor", "host");
    anyhow::ensure!(
        executor == "host" || executor == "artifact",
        "unknown executor {executor:?} (host|artifact)"
    );
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let workers = args.usize_or("workers", 2).max(1);
    let requests = args.usize_or("requests", 24);
    let rate = args.f64_or("rate", 4.0);
    let n = args.usize_or("n", 384);
    let max_new = args.usize_or("new", 8);
    let budget = args.usize_or("budget", 192);
    let seed = args.u64_or("seed", 0);
    let trace_out = args.get("trace-out").map(PathBuf::from);

    if let Some(scenario) = args.get("chaos") {
        anyhow::ensure!(scenario == "kill-one", "unknown chaos scenario {scenario:?} (kill-one)");
        anyhow::ensure!(executor == "host", "chaos scenarios need the host executor");
        return run_chaos(workers, requests, n, max_new, budget, seed);
    }
    if args.flag("mixed") {
        anyhow::ensure!(executor == "host", "the mixed-load scenario needs the host executor");
        let chunk = args.usize_or("prefill-chunk", 32).max(1);
        return run_mixed(requests, n, max_new, budget, seed, chunk);
    }
    if args.flag("paged") {
        anyhow::ensure!(executor == "host", "the paged scenario needs the host executor");
        let pct = args.u64_or("kv-budget-pct", 25).max(1);
        let dtype = args.get_or("kv-dtype", "f32");
        return run_paged(workers, requests, n, max_new, budget, seed, pct, &dtype);
    }

    println!("executor: {executor} workers: {workers}");
    let mut table = Table::new(&["policy", "completed", "tok/s", "p50", "p90", "p99", "max"]);
    let mut tracks: Vec<(String, Vec<TraceEvent>)> = Vec::new();
    for policy in ["exact", "sink", "h2o", "subgen"] {
        let (report, snap, policy_tracks) = run_policy(
            &executor,
            &artifacts,
            workers,
            policy,
            requests,
            rate,
            n,
            max_new,
            budget,
            seed,
            trace_out.is_some(),
        )?;
        table.row(&[
            policy.to_string(),
            format!("{}/{}", report.completed, requests),
            format!("{:.1}", report.throughput_tps()),
            format!("{:?}", report.latency.quantile(0.50)),
            format!("{:?}", report.latency.quantile(0.90)),
            format!("{:?}", report.latency.quantile(0.99)),
            format!("{:?}", report.latency.max()),
        ]);
        let total = snap.completed.max(1);
        for w in &snap.workers {
            println!(
                "cluster policy={policy} worker={} dispatched={} completed={} rejected={} \
                 tokens={} batch={:.2} share={:.2}",
                w.worker,
                w.dispatched,
                w.completed,
                w.rejected,
                w.tokens,
                w.mean_batch(),
                w.completed as f64 / total as f64
            );
        }
        println!(
            "cluster policy={policy} aggregate tokens_per_sec={:.1} completed={} rejected={} \
             p50={:?} p99={:?}",
            snap.tokens_per_sec, snap.completed, snap.rejected, snap.latency.p50, snap.latency.p99
        );
        if !policy_tracks.is_empty() {
            // Request ids repeat across policy runs, so summarise per
            // policy over the union of this policy's worker rings.
            let merged: Vec<TraceEvent> =
                policy_tracks.iter().flat_map(|(_, evs)| evs.iter().copied()).collect();
            for s in request_summaries(&merged) {
                println!("trace policy={policy} {s}");
            }
            tracks.extend(policy_tracks);
        }
    }
    println!();
    table.print();
    if let Some(path) = trace_out {
        let events: usize = tracks.iter().map(|(_, evs)| evs.len()).sum();
        std::fs::write(&path, chrome_trace(&tracks))?;
        println!("trace written path={} tracks={} events={events}", path.display(), tracks.len());
    }
    Ok(())
}

/// Chaos scenario `kill-one`: the same streaming workload twice — an
/// undisturbed baseline, then a run where worker 0 is killed by an
/// injected panic mid-decode and the supervisor restores its sessions
/// from per-tick snapshots. Reports worker restarts, recovered
/// sessions, and TTFT/TPOT degradation (faulted p95 / baseline p95),
/// then dumps the faulted run's Prometheus families so scrapes and CI
/// greps see the same counters. Both runs trace into per-worker flight
/// recorders; before each restart the supervisor writes the dead
/// incarnation's ring to disk, reported as one
/// `chaos flight_recorder_dump path=...` line per dump (CI greps
/// these). Arrivals are a burst (the configured
/// rate is ignored) so the killed worker deterministically holds
/// in-flight sessions when the fault fires.
fn run_chaos(
    workers: usize,
    requests: usize,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
) -> Result<()> {
    let model_seed = seed ^ 0xBEEF;
    // Tracing is on for both runs (identical overhead keeps the
    // degradation comparison fair); the faulted run adds a dump dir so
    // the supervisor leaves a crash-forensics trace behind.
    let cfg = EngineConfig::builder()
        .max_active(4)
        .prefills_per_tick(1)
        .snapshot_every(1)
        .trace_buffer(1 << 16)
        .build();
    let dump_dir = std::env::temp_dir().join("subgen_chaos_dumps");
    let _ = std::fs::remove_dir_all(&dump_dir);
    // Identical prompts in both runs so the latency comparison is
    // workload-for-workload.
    let load = || {
        let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
        let mut prompts = Vec::with_capacity(requests);
        for _ in 0..requests {
            let inst = sampler.sample(lines_for_seq_len_clamped(n));
            prompts.push(inst.tokens().0);
        }
        let make_request = Box::new(move |id: u64| Request {
            id,
            session_id: None,
            prompt: prompts[id as usize].clone(),
            max_new,
            policy: "subgen".into(),
            budget,
            delta: 4.0,
            deadline: None,
            class: RequestClass::Interactive,
        });
        LoadGen { rate: 1e6, requests, make_request, seed }
    };

    let baseline_router =
        Router::spawn(workers, cfg.clone(), move |_w| HostExecutor::retrieval(model_seed))?;
    let baseline = load().run_streaming(&baseline_router);
    baseline_router.shutdown()?;

    let rcfg = RouterConfig::builder()
        .fault_plans(vec![(0, FaultPlan { panic_at_tick: Some(8), ..Default::default() })])
        .trace_dump_dir(Some(dump_dir))
        .build();
    let router =
        Router::spawn_with(workers, cfg, rcfg, move |_w| HostExecutor::retrieval(model_seed))?;
    let faulted = load().run_streaming(&router);
    let metrics = router.metrics();
    let snap = router.shutdown()?;
    let trace_dumps: Vec<PathBuf> =
        metrics.trace_dumps().into_iter().map(|(_, path)| path).collect();

    let chaos = ChaosReport {
        baseline,
        faulted,
        restarts: snap.restarts,
        recovered_sessions: snap.recovered_sessions,
        trace_dumps,
    };
    println!(
        "chaos scenario=kill-one restarts={} recovered_sessions={} completed={}/{requests} \
         failed={} ttft_degradation={:.2} tpot_degradation={:.2}",
        chaos.restarts,
        chaos.recovered_sessions,
        chaos.faulted.completed,
        chaos.faulted.failed,
        chaos.ttft_degradation(),
        chaos.tpot_degradation()
    );
    println!(
        "chaos baseline ttft_p95={:?} tpot_p95={:?}; faulted ttft_p95={:?} tpot_p95={:?}",
        chaos.baseline.ttft.p95(),
        chaos.baseline.tpot.p95(),
        chaos.faulted.ttft.p95(),
        chaos.faulted.tpot.p95()
    );
    for path in &chaos.trace_dumps {
        println!("chaos flight_recorder_dump path={}", path.display());
    }
    print!("{}", prometheus_text(&snap));
    Ok(())
}

/// Memory-pressure scenario `--paged`: the same burst workload twice —
/// an unbounded reference pass, then a pass whose shared KV page pool
/// is budgeted to `--kv-budget-pct` percent of the decode working set
/// (`max_active` prompt-capacity carry arenas), forcing cold pages out
/// to disk between sweeps and back in at every pin. Every session must
/// still complete with tokens bit-identical to the reference; the run
/// reports one `paged ... tokens_match=...` line (CI greps it, with
/// `evicted_pages`/`recalled_pages` nonzero) and dumps the budgeted
/// pass's Prometheus families so the `subgen_pages_*` series are
/// scrape-visible under real pressure.
///
/// `--kv-dtype` re-runs the whole scenario on an encoded cache; the
/// pool budget is always sized off the *f32* working set, so the
/// reported `spilled_bytes` (cumulative spill traffic) is directly
/// comparable across encodings — CI asserts int8 spills fewer bytes
/// than f32 under the identical budget.
fn run_paged(
    workers: usize,
    requests: usize,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
    pct: u64,
    dtype: &str,
) -> Result<()> {
    let model_seed = seed ^ 0xBEEF;
    // Chunked prefill + per-tick snapshots: the pressure run exercises
    // paged mid-prefill carries and spill-manifest snapshots, not just
    // decode arenas.
    let cfg = EngineConfig::builder()
        .max_active(4)
        .prefills_per_tick(2)
        .prefill_chunk(32)
        .snapshot_every(1)
        .kv_dtype(dtype)
        .build();
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut prompts = Vec::with_capacity(requests);
    for _ in 0..requests {
        prompts.push(sampler.sample(lines_for_seq_len_clamped(n)).tokens().0);
    }
    let make = |id: usize| Request {
        id: id as u64,
        session_id: None,
        prompt: prompts[id].clone(),
        max_new,
        policy: "subgen".into(),
        budget,
        delta: 4.0,
        deadline: None,
        class: RequestClass::Interactive,
    };

    // Reference pass: unbounded pool, everything submitted up front so
    // the scheduler reaches full concurrency.
    let router =
        Router::spawn(workers, cfg.clone(), move |_w| HostExecutor::retrieval(model_seed))?;
    let rxs: Vec<_> = (0..requests)
        .map(|id| router.submit(make(id)).map_err(|e| anyhow::anyhow!("submit {id}: {e}")))
        .collect::<Result<_>>()?;
    let mut reference = Vec::with_capacity(requests);
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = subgen::server::recv_reply(&rx)
            .map_err(|e| anyhow::anyhow!("reference request {id}: {e}"))?;
        reference.push(resp.tokens);
    }
    router.shutdown()?;

    // Size the budget off the *f32* decode working set: `max_active`
    // prompt-capacity carry arenas (the largest allocations a sweep
    // pins at once). Encoded runs keep the same byte budget — that is
    // the point of the dtype comparison: same pool, less traffic.
    let probe = HostExecutor::retrieval(model_seed);
    let max_prompt = prompts.iter().map(|p| p.len()).max().unwrap_or(n);
    let arena = FlatCaches::for_prefill(probe.spec(), max_prompt + max_new).serialized_len() as u64;
    let kv_budget = (4 * arena * pct / 100).max(1);

    let rcfg = RouterConfig::builder()
        .kv_mem_budget(Some(kv_budget))
        .spill_dir(Some(std::env::temp_dir()))
        .build();
    let router =
        Router::spawn_with(workers, cfg, rcfg, move |_w| HostExecutor::retrieval(model_seed))?;
    // A budgeted pool sheds submits that race a fully pinned decode
    // sweep (the router's overload gate); clients retry exactly like
    // any 503 — pins drop between sweeps, so a retry lands promptly.
    let mut shed_retries = 0u64;
    let mut rxs = Vec::with_capacity(requests);
    for id in 0..requests {
        let rx = loop {
            match router.submit(make(id)) {
                Ok(rx) => break rx,
                Err(SubmitError::PoolExhausted) if shed_retries < 10_000 => {
                    shed_retries += 1;
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(e) => anyhow::bail!("request {id} failed under memory pressure: {e}"),
            }
        };
        rxs.push(rx);
    }
    let mut paged = Vec::with_capacity(requests);
    for (id, rx) in rxs.into_iter().enumerate() {
        let resp = subgen::server::recv_reply(&rx)
            .map_err(|e| anyhow::anyhow!("budgeted request {id}: {e}"))?;
        paged.push(resp.tokens);
    }
    let stats = router.metrics().pool().stats();
    let snap = router.shutdown()?;
    let matched = paged == reference;
    // `spilled_bytes` here is the cumulative spill traffic
    // (PoolStats::evicted_bytes): the point-in-time gauge drains as
    // leases release, the counter is what the dtype comparison needs.
    println!(
        "paged policy=subgen workers={workers} dtype={dtype} budget_bytes={kv_budget} pct={pct} \
         completed={}/{requests} shed_retries={shed_retries} evicted_pages={} \
         recalled_pages={} spilled_bytes={} ghost_hits={} tokens_match={matched}",
        paged.len(),
        stats.evicted_pages,
        stats.recalled_pages,
        stats.evicted_bytes,
        stats.ghost_hits
    );
    print!("{}", prometheus_text(&snap));
    anyhow::ensure!(matched, "budgeted decode diverged from the unbounded reference");
    anyhow::ensure!(
        stats.evicted_pages > 0 && stats.recalled_pages > 0,
        "the budget never forced paging: {stats:?}"
    );
    Ok(())
}

/// Mixed-load scenario: long-prompt **batch** prefills interleaved
/// with short-prompt **interactive** requests on a single worker, so
/// the two classes contend for the same tick loop. The workload runs
/// twice — monolithic prefill (`prefill_chunk = 0`) and chunked — and
/// reports per-class `ttft_p95`/`tpot_p95` lines (CI greps these), the
/// headline comparison (`improved=true` when chunking lowered
/// interactive p95 TTFT), and the chunked run's Prometheus families
/// (`subgen_prefill_chunks_total` & co).
fn run_mixed(
    requests: usize,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
    chunk: usize,
) -> Result<()> {
    let requests = requests.max(8);
    println!("mixed-load: requests={requests} n={n} prefill_chunk={chunk} (vs monolithic)");
    let (mono_report, _) = run_mixed_once(requests, n, max_new, budget, seed, 0)?;
    let (chunked_report, snap) = run_mixed_once(requests, n, max_new, budget, seed, chunk)?;
    for (label, report) in [(0usize, &mono_report), (chunk, &chunked_report)] {
        for class in [RequestClass::Interactive, RequestClass::Batch] {
            println!(
                "mixed prefill_chunk={label} class={} ttft_p95={:?} tpot_p95={:?} streams={}",
                class.label(),
                report.ttft_for(class).p95(),
                report.tpot_for(class).p95(),
                report.ttft_for(class).count(),
            );
        }
    }
    let (mono, chunked) =
        (mono_report.ttft_interactive.p95(), chunked_report.ttft_interactive.p95());
    println!(
        "mixed interactive ttft_p95 monolithic={mono:?} chunked={chunked:?} improved={}",
        chunked < mono
    );
    print!("{}", prometheus_text(&snap));
    Ok(())
}

/// One mixed-load pass at a given prefill chunk budget (0 = monolithic).
/// Even ids are batch-class with ~`n`-token prompts, odd ids are
/// interactive with short prompts, arriving as an open-loop Poisson
/// stream whose mean gap is comparable to one long prefill — so
/// interactive requests routinely land while a batch prefill is in
/// flight, which is exactly the head-of-line blocking a chunked
/// scheduler bounds to one chunk.
fn run_mixed_once(
    requests: usize,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
    chunk: usize,
) -> Result<(StreamingReport, ClusterSnapshot)> {
    let model_seed = seed ^ 0xBEEF;
    let cfg = EngineConfig::builder()
        .max_active(4)
        .prefills_per_tick(1)
        .prefill_chunk(chunk)
        .build();
    let router = Router::spawn(1, cfg, move |_w| HostExecutor::retrieval(model_seed))?;
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut prompts = Vec::with_capacity(requests);
    for id in 0..requests {
        let lines = if id % 2 == 0 { lines_for_seq_len_clamped(n) } else { 2 };
        prompts.push(sampler.sample(lines).tokens().0);
    }
    let make_request = Box::new(move |id: u64| {
        let class =
            if id % 2 == 0 { RequestClass::Batch } else { RequestClass::Interactive };
        Request {
            id,
            session_id: None,
            prompt: prompts[id as usize].clone(),
            max_new,
            policy: "subgen".into(),
            budget,
            delta: 4.0,
            deadline: None,
            class,
        }
    });
    let report =
        LoadGen { rate: 400.0, requests, make_request, seed }.run_streaming(&router);
    let snap = router.shutdown()?;
    Ok((report, snap))
}

/// One policy's run: spawn the serving backend, drive the open-loop
/// load, drain, and return (load report, final cluster snapshot,
/// flight-recorder tracks — empty unless `trace` is on).
fn run_policy(
    executor: &str,
    artifacts: &std::path::Path,
    workers: usize,
    policy: &str,
    requests: usize,
    rate: f64,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
    trace: bool,
) -> Result<(LoadGenReport, ClusterSnapshot, Vec<(String, Vec<TraceEvent>)>)> {
    let policy_owned = policy.to_string();
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut prompts = Vec::with_capacity(requests);
    for _ in 0..requests {
        let inst = sampler.sample(lines_for_seq_len_clamped(n));
        prompts.push(inst.tokens().0);
    }
    let make_request = Box::new(move |id: u64| Request {
        id,
        session_id: None,
        prompt: prompts[id as usize].clone(),
        max_new,
        policy: policy_owned.clone(),
        budget,
        delta: 4.0,
        deadline: None,
        class: RequestClass::Interactive,
    });
    let mut builder = EngineConfig::builder().max_active(4).prefills_per_tick(1);
    if trace {
        builder = builder.trace_buffer(1 << 16);
    }
    let cfg = builder.build();
    let loadgen = LoadGen { rate, requests, make_request, seed };

    if executor == "host" {
        // Same model seed on every worker: identical responses
        // regardless of placement.
        let model_seed = seed ^ 0xBEEF;
        let router = Router::spawn(workers, cfg, move |_w| HostExecutor::retrieval(model_seed))?;
        let report = loadgen.run(&router);
        let mut tracks = Vec::new();
        for w in 0..router.num_workers() {
            if let Some(rec) = router.recorder(w) {
                tracks.push((format!("{policy}/worker{w}"), rec.events()));
            }
        }
        let snap = router.shutdown()?;
        Ok((report, snap, tracks))
    } else {
        // PJRT types are not Send: single engine thread, runtime built
        // inside it; wrap the snapshot from its one stats block. The
        // recorder is pre-built here so the trace survives the engine.
        let recorder = trace.then(|| std::sync::Arc::new(FlightRecorder::new(1 << 16, 1)));
        let cfg = EngineConfig::builder()
            .max_active(4)
            .prefills_per_tick(1)
            .trace(recorder.clone())
            .build();
        let (handle, rx) = channel();
        let artifacts = artifacts.to_path_buf();
        let engine_thread = std::thread::spawn(move || -> Result<_> {
            let rt = Runtime::load(&artifacts, None)?;
            let spec = ModelSpec::from_manifest(rt.manifest())?;
            let generator = Generator::new(&rt, spec);
            serve(&generator, cfg, rx)
        });
        let report = loadgen.run(&handle);
        handle.shutdown();
        let stats = engine_thread.join().unwrap()?;
        // After the drain, the engine settled exactly what it received.
        let received = stats.completed.get() + stats.rejected.get();
        let snap = ClusterSnapshot::from_engine_stats(
            &stats,
            received,
            report.throughput_tps(),
            report.wall,
        );
        let tracks = recorder
            .map(|rec| vec![(format!("{policy}/worker0"), rec.events())])
            .unwrap_or_default();
        Ok((report, snap, tracks))
    }
}
