//! End-to-end serving driver (the serving-paper e2e requirement):
//! batched requests against the engine under open-loop Poisson load,
//! reporting latency percentiles and throughput per cache policy.
//!
//!     cargo run --release --example serving_throughput [-- --requests 24]
//!
//! The headline serving claim of a KV-compression paper is that smaller
//! caches keep decode latency flat as contexts grow; compressed policies
//! run on smaller cache-capacity executables, so the per-step buffer
//! traffic scales with the *budget*, not the context.
//!
//! `--executor host` (the default) serves the load from the pure-rust
//! [`subgen::model::HostExecutor`] — no PJRT artifacts required;
//! `--executor artifact` restores the compiled-executable path.

use anyhow::Result;
use std::path::PathBuf;
use subgen::bench::Table;
use subgen::cli::Args;
use subgen::coordinator::{EngineConfig, HostExecutor, Request};
use subgen::model::{Generator, ModelSpec};
use subgen::rng::Pcg64;
use subgen::runtime::Runtime;
use subgen::server::{channel, serve, LoadGen};
use subgen::workload::{lines_for_seq_len, RetrievalSampler};

fn main() -> Result<()> {
    let args = Args::from_env("serving throughput under Poisson load")
        .describe("executor", Some("host"), "decode backend (host|artifact)")
        .describe("artifacts", Some("artifacts"), "artifacts directory (artifact executor)")
        .describe("requests", Some("24"), "requests per policy")
        .describe("rate", Some("4.0"), "mean arrival rate (req/s)")
        .describe("n", Some("384"), "prompt length (tokens)")
        .describe("new", Some("8"), "tokens generated per request")
        .describe("budget", Some("192"), "per-head budget for compressed policies")
        .describe("seed", Some("0"), "rng seed");
    args.exit_on_help();
    let executor = args.get_or("executor", "host");
    anyhow::ensure!(
        executor == "host" || executor == "artifact",
        "unknown executor {executor:?} (host|artifact)"
    );
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let requests = args.usize_or("requests", 24);
    let rate = args.f64_or("rate", 4.0);
    let n = args.usize_or("n", 384);
    let max_new = args.usize_or("new", 8);
    let budget = args.usize_or("budget", 192);
    let seed = args.u64_or("seed", 0);

    println!("executor: {executor}");
    let mut table = Table::new(&["policy", "completed", "tok/s", "p50", "p90", "p99", "max"]);
    for policy in ["exact", "sink", "h2o", "subgen"] {
        let report = run_policy(
            &executor, &artifacts, policy, requests, rate, n, max_new, budget, seed,
        )?;
        table.row(&[
            policy.to_string(),
            format!("{}/{}", report.completed, requests),
            format!("{:.1}", report.throughput_tps()),
            format!("{:?}", report.latency.quantile(0.50)),
            format!("{:?}", report.latency.quantile(0.90)),
            format!("{:?}", report.latency.quantile(0.99)),
            format!("{:?}", report.latency.max()),
        ]);
    }
    println!();
    table.print();
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_policy(
    executor: &str,
    artifacts: &std::path::Path,
    policy: &str,
    requests: usize,
    rate: f64,
    n: usize,
    max_new: usize,
    budget: usize,
    seed: u64,
) -> Result<subgen::server::LoadGenReport> {
    let (handle, rx) = channel();
    let executor = executor.to_string();
    let artifacts = artifacts.to_path_buf();
    let engine_thread = std::thread::spawn(move || -> Result<_> {
        let cfg = EngineConfig { max_active: 4, prefills_per_tick: 1, ..Default::default() };
        if executor == "host" {
            let exec = HostExecutor::retrieval(seed ^ 0xBEEF);
            serve(&exec, cfg, rx)
        } else {
            // PJRT types are not Send: build the runtime inside the thread.
            let rt = Runtime::load(&artifacts, None)?;
            let spec = ModelSpec::from_manifest(rt.manifest())?;
            let generator = Generator::new(&rt, spec);
            serve(&generator, cfg, rx)
        }
    });

    let policy_owned = policy.to_string();
    let mut sampler = RetrievalSampler::new(Pcg64::seed_from_u64(seed));
    let mut prompts = Vec::with_capacity(requests);
    for _ in 0..requests {
        let inst = sampler.sample(lines_for_seq_len(n));
        prompts.push(inst.tokens().0);
    }
    let report = LoadGen {
        rate,
        requests,
        make_request: Box::new(move |id| Request {
            id,
            prompt: prompts[id as usize].clone(),
            max_new,
            policy: policy_owned.clone(),
            budget,
            delta: 4.0,
        }),
        seed,
    }
    .run(&handle);
    handle.shutdown();
    engine_thread.join().unwrap()?;
    Ok(report)
}
