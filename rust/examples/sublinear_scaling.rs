//! Theorem 1 / Corollary 1 validation (experiment TH1 in DESIGN.md):
//! memory and per-step time of SubGen vs the exact cache as the stream
//! grows, plus the (1±ε) partition-function guarantee vs t.
//!
//!     cargo run --release --example sublinear_scaling [-- --max-n 65536]
//!
//! Prints the measured log-log scaling exponents: exact is Θ(n) (slope
//! ≈ 1); SubGen with a fixed planted m must plateau (slope ≈ 0); with
//! m = √n the slope must stay well below 1.

use std::time::Instant;
use subgen::attention::exact_log_partition;
use subgen::bench::Table;
use subgen::cli::Args;
use subgen::linalg::loglog_slope;
use subgen::rng::Pcg64;
use subgen::subgen::{SubGenAttention, SubGenConfig};
use subgen::tensor::Tensor;
use subgen::workload::{ClusterableStream, TokenStream};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env("TH1: sublinear memory/time scaling")
        .describe("max-n", Some("65536"), "largest stream length")
        .describe("dim", Some("32"), "embedding dim");
    args.exit_on_help();
    let max_n = args.usize_or("max-n", 65_536);
    let dim = args.usize_or("dim", 32);

    println!("== memory & update time vs n (fixed m = 16 clusters) ==\n");
    let mut ns = Vec::new();
    let mut n_i = 1024usize;
    while n_i <= max_n {
        ns.push(n_i);
        n_i *= 2;
    }

    let mut table = Table::new(&[
        "n",
        "subgen bytes",
        "exact bytes",
        "update µs/token",
        "query µs",
        "clusters",
    ]);
    let mut mem_series = Vec::new();
    let mut upd_series = Vec::new();
    let mut qry_series = Vec::new();
    for &n in &ns {
        let cfg = SubGenConfig { dim, delta: 0.5, t: 32, s: 64 };
        let mut sketch = SubGenAttention::new(cfg, 1);
        let mut stream = ClusterableStream::new(dim, 16, 0.05, 1.0, 9);
        let t0 = Instant::now();
        let mut q = vec![0.0f32; dim];
        for _ in 0..n {
            let (qq, k, v) = stream.next_triplet();
            sketch.update(&k, &v);
            q = qq;
        }
        let update_us = t0.elapsed().as_micros() as f64 / n as f64;
        let t1 = Instant::now();
        let reps = 50;
        for _ in 0..reps {
            std::hint::black_box(sketch.query(&q));
        }
        let query_us = t1.elapsed().as_micros() as f64 / reps as f64;
        let exact_bytes = n * subgen::kvcache::bytes_per_slot(dim);
        table.row(&[
            n.to_string(),
            sketch.memory_bytes().to_string(),
            exact_bytes.to_string(),
            format!("{update_us:.2}"),
            format!("{query_us:.1}"),
            sketch.num_clusters().to_string(),
        ]);
        mem_series.push(sketch.memory_bytes() as f64);
        upd_series.push(update_us);
        qry_series.push(query_us);
    }
    table.print();
    let nsf: Vec<f64> = ns.iter().map(|&x| x as f64).collect();
    println!("\nlog-log slopes (exact cache memory would be 1.0):");
    println!("  subgen memory : {:+.3}", loglog_slope(&nsf, &mem_series));
    println!("  update time   : {:+.3}", loglog_slope(&nsf, &upd_series));
    println!("  query time    : {:+.3}", loglog_slope(&nsf, &qry_series));

    println!("\n== partition function (1±ε) vs t (n = 4096, m = 8) ==\n");
    let mut t2 = Table::new(&["t", "mean rel err", "max rel err", "1/sqrt(t)"]);
    for t in [4usize, 8, 16, 32, 64, 128] {
        let mut errs = Vec::new();
        for seed in 0..5u64 {
            let cfg = SubGenConfig { dim, delta: 0.5, t, s: 8 };
            let mut sketch = SubGenAttention::new(cfg, seed);
            let mut stream = ClusterableStream::new(dim, 8, 0.05, 1.0, 100 + seed);
            let mut keys = Tensor::zeros(0, dim);
            let mut q = vec![0.0f32; dim];
            for _ in 0..4096 {
                let (qq, k, v) = stream.next_triplet();
                sketch.update(&k, &v);
                keys.push_row(&k);
                q = qq;
            }
            let est = sketch.partition_estimate(&q);
            let exact = exact_log_partition(&q, &keys).exp() as f64;
            errs.push(((est - exact) / exact).abs());
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        let max = errs.iter().cloned().fold(0.0, f64::max);
        t2.row(&[
            t.to_string(),
            format!("{mean:.4}"),
            format!("{max:.4}"),
            format!("{:.4}", 1.0 / (t as f64).sqrt()),
        ]);
    }
    t2.print();

    println!("\n== adversarial stream: δ-doubling keeps memory bounded ==\n");
    let mut sketch = SubGenAttention::new(SubGenConfig { dim, delta: 0.3, t: 8, s: 16 }, 3);
    let mut stream = subgen::workload::AdversarialStream::new(dim, 5);
    let mut rng = Pcg64::seed_from_u64(0);
    let _ = &mut rng;
    for i in 0..20_000 {
        let (_, k, v) = stream.next_triplet();
        sketch.update(&k, &v);
        sketch.enforce_cluster_cap(64);
        if (i + 1) % 5000 == 0 {
            println!(
                "  n={:>6}  clusters={:>3}  memory={}",
                i + 1,
                sketch.num_clusters(),
                subgen::bench::fmt_bytes(sketch.memory_bytes())
            );
        }
    }
    Ok(())
}
