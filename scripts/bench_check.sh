#!/usr/bin/env bash
# Perf-regression gate over the committed bench baseline.
#
# Re-runs `cargo bench --bench bench_query_latency` (which rewrites
# BENCH_query.json at the repo root) and compares every `*_ns` timing
# against the previously committed baseline. Exits non-zero when a
# timing regresses beyond the tolerance (BENCH_TOLERANCE, default 0.25
# = 25%). Per the ROADMAP open item, the baseline does not exist until
# the first CI bench run commits it — a missing baseline is a clean
# skip, not a failure, so this script can gate CI from day one.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BASELINE="$ROOT/BENCH_query.json"
TOLERANCE="${BENCH_TOLERANCE:-0.25}"

if [ ! -f "$BASELINE" ]; then
  echo "bench_check: no committed BENCH_query.json baseline yet — skipping" \
       "(trigger the CI bench job and commit the artifact to arm this gate)"
  exit 0
fi

SAVED="$(mktemp /tmp/bench_baseline.XXXXXX.json)"
cp "$BASELINE" "$SAVED"
trap 'rm -f "$SAVED"' EXIT

(cd "$ROOT/rust" && cargo bench --bench bench_query_latency)

python3 - "$ROOT/BENCH_query.json" "$SAVED" "$TOLERANCE" <<'EOF'
import json
import sys

fresh = json.load(open(sys.argv[1]))
base = json.load(open(sys.argv[2]))
tol = float(sys.argv[3])


def walk(node, prefix=""):
    if isinstance(node, dict):
        for key, val in node.items():
            yield from walk(val, f"{prefix}{key}.")
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield prefix.rstrip("."), float(node)


base_vals = dict(walk(base))
regressions = []
for key, val in walk(fresh):
    if not key.endswith("_ns") or base_vals.get(key, 0) <= 0:
        continue
    ratio = val / base_vals[key]
    status = "REGRESSION" if ratio > 1 + tol else "ok"
    print(f"bench_check: {key}: {base_vals[key]:.0f} -> {val:.0f} ns (x{ratio:.2f}) {status}")
    if ratio > 1 + tol:
        regressions.append(key)
if regressions:
    sys.exit(
        f"bench_check: {len(regressions)} timing(s) regressed beyond "
        f"{tol:.0%}: {', '.join(regressions)}"
    )
print("bench_check: all timings within tolerance")
EOF
