#!/usr/bin/env bash
# Perf-regression gate over the committed bench baseline.
#
# Re-runs the BENCH_query.json emitters — `cargo bench --bench
# bench_query_latency` (rewrites the file) then `cargo bench --bench
# bench_e2e_decode` (merges its `batched_decode`, `prefill_chunked`,
# `trace_overhead`, and `paged_decode` operating points into it) — and
# compares every `*_ns` timing against the previously
# committed baseline. Exits non-zero when a timing regresses beyond the
# tolerance (BENCH_TOLERANCE, default 0.25 = 25%) **or when a `*_ns`
# key present in the baseline is missing from the fresh run** — a
# silently dropped operating point must fail the gate, not skip it.
# A per-key before/after table is printed either way.
#
# A baseline stamped `"provenance": "seeded"` (hand-written magnitudes
# committed so the structural gate — key coverage — is live before the
# first CI bench run on this hardware) relaxes the *magnitude* check to
# warn-only: seeded numbers are not this machine's numbers, so ratios
# against them prove nothing. Missing keys still fail — a dropped
# operating point is structural, not a magnitude. The bench emitters
# stamp `"provenance": "measured"`, so the first CI bench run that
# commits its output replaces the seeded file and the magnitude check
# becomes blocking from then on.
#
# A missing baseline *file* is a clean skip, so this script can gate CI
# from day one.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
BASELINE="$ROOT/BENCH_query.json"
TOLERANCE="${BENCH_TOLERANCE:-0.25}"

if [ ! -f "$BASELINE" ]; then
  echo "bench_check: no committed BENCH_query.json baseline yet — skipping" \
       "(the CI bench job on main produces and commits it)"
  exit 0
fi

SAVED="$(mktemp /tmp/bench_baseline.XXXXXX.json)"
cp "$BASELINE" "$SAVED"
trap 'rm -f "$SAVED"' EXIT

(cd "$ROOT/rust" && cargo bench --bench bench_query_latency)
(cd "$ROOT/rust" && cargo bench --bench bench_e2e_decode)

python3 - "$ROOT/BENCH_query.json" "$SAVED" "$TOLERANCE" <<'EOF'
import json
import sys

fresh = json.load(open(sys.argv[1]))
base = json.load(open(sys.argv[2]))
tol = float(sys.argv[3])


def walk(node, prefix=""):
    if isinstance(node, dict):
        for key, val in node.items():
            yield from walk(val, f"{prefix}{key}.")
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        yield prefix.rstrip("."), float(node)


base_vals = {k: v for k, v in walk(base) if k.endswith("_ns")}
fresh_vals = {k: v for k, v in walk(fresh) if k.endswith("_ns")}
seeded = base.get("provenance") == "seeded"

rows = []
regressions = []
missing = []
for key in sorted(base_vals.keys() | fresh_vals.keys()):
    b, f = base_vals.get(key), fresh_vals.get(key)
    if f is None:
        missing.append(key)
        rows.append((key, f"{b:.0f}", "MISSING", "-", "MISSING"))
        continue
    if b is None or b <= 0:
        rows.append((key, "-", f"{f:.0f}", "-", "new (no baseline)"))
        continue
    ratio = f / b
    status = "REGRESSION" if ratio > 1 + tol else "ok"
    if ratio > 1 + tol:
        regressions.append(key)
    rows.append((key, f"{b:.0f}", f"{f:.0f}", f"x{ratio:.2f}", status))

widths = [max(len(r[i]) for r in rows + [("key", "baseline ns", "current ns", "ratio", "status")])
          for i in range(5)]
header = ("key", "baseline ns", "current ns", "ratio", "status")
for row in [header] + rows:
    print("bench_check: " + "  ".join(c.ljust(w) for c, w in zip(row, widths)))

if missing:
    sys.exit(
        f"bench_check: {len(missing)} baseline timing(s) missing from the fresh "
        f"run (dropped operating point?): {', '.join(missing)}"
    )
if regressions:
    if seeded:
        print(
            f"bench_check: baseline is seeded (hand-written magnitudes) — "
            f"{len(regressions)} out-of-tolerance timing(s) reported as "
            f"warnings only: {', '.join(regressions)}"
        )
    else:
        sys.exit(
            f"bench_check: {len(regressions)} timing(s) regressed beyond "
            f"{tol:.0%}: {', '.join(regressions)}"
        )
else:
    print("bench_check: all timings within tolerance")
EOF
